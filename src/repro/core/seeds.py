"""Piecewise-linear seed generation for the Taylor-series reciprocal (paper §3).

Implements, in exact float64 numpy (this is table *generation*, done once,
offline — the hardware analogue is the ROM content):

  * the optimal single-segment linear seed  y0 = -4x/(a+b)^2 + 4/(a+b)
    (paper eq. 15, derived from minimizing eq. 14 at p = (a+b)/2),
  * the per-segment error bound of the n-term Taylor refinement
    (paper eq. 17):  E_n <= ((a+b)^2 / 4ab)^(n+2) * m_max^(n+1)
    with m_max = ((b-a)/(a+b))^2  (the maximum of m(x) = 1 - x*y0(x), which
    is ((a+b-2x)/(a+b))^2 on the segment, maximal at the endpoints),
  * the segment-boundary recurrence (paper eq. 19/20): given n and a target
    precision, grow segments [b_{k-1}, b_k] left-to-right so each segment
    *just* meets the precision in n iterations. Table I of the paper is
    ``compute_segments(5, 53)``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from functools import lru_cache

import numpy as np

__all__ = [
    "SeedTable",
    "linear_seed_coeffs",
    "seed_error_bound",
    "iterations_required",
    "compute_segments",
    "rsqrt_seed_table",
    "PAPER_TABLE_I",
]

# Paper Table I (n = 5, 53-bit precision): reproduced by compute_segments(5, 53).
PAPER_TABLE_I = [1.09811, 1.20835, 1.3269, 1.45709, 1.59866, 1.75616, 1.92922, 2.12392]


def linear_seed_coeffs(a: float, b: float) -> tuple[float, float]:
    """Optimal linear approximation of 1/x on [a, b] (paper eq. 15).

    Returns (slope, intercept) of y0(x) = slope*x + intercept.
    Minimizes the integrated error (eq. 14); optimum at p = (a+b)/2.
    """
    p = 0.5 * (a + b)
    return (-1.0 / (p * p), 2.0 / p)


def seed_max_m(a: float, b: float) -> float:
    """max_x |1 - x*y0(x)| over [a,b] for the optimal seed: ((b-a)/(a+b))^2."""
    return ((b - a) / (a + b)) ** 2


def seed_error_bound(a: float, b: float, n: int) -> float:
    """Paper eq. 17: upper bound on the reciprocal error after n Taylor terms.

    E_n(x, y0) <= ((a+b)^2 / 4ab)^(n+2) * m_max^(n+1)
    """
    amp = (a + b) ** 2 / (4.0 * a * b)
    return amp ** (n + 2) * seed_max_m(a, b) ** (n + 1)


def iterations_required(a: float, b: float, precision_bits: int, n_max: int = 64) -> int:
    """Smallest n such that seed_error_bound(a, b, n) <= 2^-precision_bits.

    Reproduces the paper's §3 claims: (1, 2, 53 bits) -> 17 iterations.
    """
    target = 2.0 ** (-precision_bits)
    for n in range(n_max + 1):
        if seed_error_bound(a, b, n) <= target:
            return n
    raise ValueError(f"no n <= {n_max} meets 2^-{precision_bits} on [{a},{b}]")


def _next_boundary(a: float, n: int, precision_bits: int, b_cap: float = 16.0) -> float:
    """Largest b > a with seed_error_bound(a, b, n) <= 2^-precision_bits (eq. 20).

    The bound is continuous, 0 at b=a and increasing in b, so bisection applies.
    """
    target = 2.0 ** (-precision_bits)
    lo, hi = a, a * 1.0000001
    # Exponential search for an upper bracket.
    while seed_error_bound(a, hi, n) <= target:
        lo = hi
        hi = a + (hi - a) * 2.0
        if hi > b_cap:
            return b_cap
    for _ in range(200):  # bisection to f64 convergence
        mid = 0.5 * (lo + hi)
        if seed_error_bound(a, mid, n) <= target:
            lo = mid
        else:
            hi = mid
        if hi - lo <= np.finfo(np.float64).eps * hi:
            break
    return lo


@dataclass(frozen=True)
class SeedTable:
    """PWL seed table: segment i covers [boundaries[i], boundaries[i+1])."""

    n_iters: int
    precision_bits: int
    boundaries: np.ndarray  # (n_segments + 1,), boundaries[0] = lo, last >= hi
    slopes: np.ndarray      # (n_segments,)
    intercepts: np.ndarray  # (n_segments,)

    @property
    def n_segments(self) -> int:
        return len(self.slopes)

    @property
    def inner_boundaries(self) -> np.ndarray:
        """Thresholds for segment lookup: idx = sum(x >= inner_boundaries)."""
        return self.boundaries[1:-1]

    def seed(self, x):
        """Vectorized numpy seed evaluation (used by the f64 oracle)."""
        x = np.asarray(x)
        idx = np.sum(x[..., None] >= self.inner_boundaries, axis=-1)
        return self.slopes[idx] * x + self.intercepts[idx]

    def max_error_bound(self, n: int | None = None) -> float:
        n = self.n_iters if n is None else n
        return max(
            seed_error_bound(float(a), float(b), n)
            for a, b in zip(self.boundaries[:-1], self.boundaries[1:])
        )


@lru_cache(maxsize=None)
def compute_segments(
    n_iters: int, precision_bits: int, lo: float = 1.0, hi: float = 2.0
) -> SeedTable:
    """Paper §3 procedure: grow segments until b_k >= hi (Table I for (5, 53))."""
    bounds = [lo]
    while bounds[-1] < hi:
        nxt = _next_boundary(bounds[-1], n_iters, precision_bits)
        if nxt <= bounds[-1] * (1 + 1e-12):
            raise ValueError(
                f"segment collapsed at {bounds[-1]}: n={n_iters} cannot reach "
                f"2^-{precision_bits}; increase n_iters"
            )
        bounds.append(nxt)
    slopes, intercepts = [], []
    for a, b in zip(bounds[:-1], bounds[1:]):
        s, c = linear_seed_coeffs(a, b)
        slopes.append(s)
        intercepts.append(c)
    return SeedTable(
        n_iters=n_iters,
        precision_bits=precision_bits,
        boundaries=np.asarray(bounds, np.float64),
        slopes=np.asarray(slopes, np.float64),
        intercepts=np.asarray(intercepts, np.float64),
    )


@lru_cache(maxsize=None)
def rsqrt_seed_table(n_segments: int = 16, lo: float = 0.5, hi: float = 2.0) -> SeedTable:
    """Beyond-paper: PWL chord seed for 1/sqrt(x) on [lo, hi) (log-uniform segments).

    Same PWL machinery as the paper's reciprocal seed, refined by Newton
    y <- y*(1.5 - 0.5*x*y^2) instead of the geometric series (the series form
    only applies to 1/x). Chord interpolation of endpoints keeps the seed
    one-sided which is irrelevant for Newton.
    """
    ratio = (hi / lo) ** (1.0 / n_segments)
    bounds = np.array([lo * ratio**i for i in range(n_segments + 1)], np.float64)
    f = lambda t: 1.0 / math.sqrt(t)
    slopes, intercepts = [], []
    for a, b in zip(bounds[:-1], bounds[1:]):
        s = (f(b) - f(a)) / (b - a)
        slopes.append(s)
        intercepts.append(f(a) - s * a)
    # worst-case relative seed error (chord): evaluate on a dense grid
    xs = np.linspace(lo, hi, 20001)
    idx = np.minimum(np.searchsorted(bounds, xs, side="right") - 1, n_segments - 1)
    seed = np.asarray(slopes)[idx] * xs + np.asarray(intercepts)[idx]
    rel = np.max(np.abs(seed * np.sqrt(xs) - 1.0))
    prec = int(-math.log2(rel)) if rel > 0 else 60
    return SeedTable(
        n_iters=0,
        precision_bits=prec,
        boundaries=bounds,
        slopes=np.asarray(slopes, np.float64),
        intercepts=np.asarray(intercepts, np.float64),
    )
