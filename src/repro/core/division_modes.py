"""Framework-wide division dispatch — the paper's unit as a first-class feature.

Every division site in the framework calls through here — attention softmax,
RMSNorm rsqrt, MoE router normalization, Adam update, loss normalization, and
the application workloads (``repro.workloads``: K-Means assignment/update
divides, Givens-QR rotation coefficients) — so the divider implementation is
one config knob:

  * ``exact``         — native XLA divide/rsqrt (the baseline the paper compares
                        against: "a full-precision hardware divider").
  * ``taylor``        — paper's unit in pure jnp (PWL seed + series). This is
                        what the dry-run lowers: division becomes FMA chains.
  * ``taylor_pallas`` — fused Pallas TPU kernels (kernels/). CPU runs them in
                        interpret mode; TPU gets real VMEM-tiled kernels.
  * ``ilm``           — bit-faithful emulation with 16-bit ILM mantissa
                        arithmetic (tests/benchmarks only; slow by design).
  * ``goldschmidt``   — Goldschmidt N/D refinement (core/goldschmidt.py),
                        sharing the paper's seed ROM; the canonical rival
                        algorithm, kept on the same n_iters dial.
  * ``goldschmidt_pallas`` — the same refinement fused into the Pallas
                        division kernel (schedule="goldschmidt" in kernels/).

Besides the scalar ops (:func:`recip`, :func:`div`, :func:`rsqrt`), the
normalization *consumers* are first-class dispatch citizens: :func:`softmax`,
:func:`rmsnorm`, and :func:`attention` route every mode through one config
knob — the Pallas modes to the fused kernels (``kernels/ops.py``, with
schedule="goldschmidt" threaded for mode="goldschmidt_pallas"), the jnp
modes to twins whose divisions/rsqrts call back into this module. Their
delivered accuracy is gated by the consumer-conformance tier
(``repro.eval.consumers`` + the softmax/rmsnorm cells of the grid).

The delivered accuracy of every mode is measured in ULPs by
``repro.eval.conformance`` (``python -m repro.eval.conformance``).

Mesh awareness: the Pallas modes are safe to call on sharded operands. The
dispatch mechanics live in ``kernels/ops.py`` — when a mesh is registered via
``repro.sharding.rules.use_mesh``, the rank >= 2 kernel entry points wrap
their tiled launches in ``shard_map`` over the batch axes so sharded operands
stay device-resident (a bare ``pallas_call`` under jit would otherwise be
silently all-gathered, since it is not GSPMD-partitionable). Nothing in this
module changes per-mode numerics based on the mesh; callers already inside a
shard_map body use ``rules.suspend_mesh()`` around their division sites.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import goldschmidt, taylor
from .fpparts import UNDERFLOW_POLICIES
from .seeds import compute_segments, rsqrt_seed_table

__all__ = ["DivisionConfig", "recip", "div", "rsqrt", "softmax", "rmsnorm",
           "attention", "EXACT", "TAYLOR", "effective_underflow"]

MODES = ("exact", "taylor", "taylor_pallas", "goldschmidt",
         "goldschmidt_pallas", "ilm")


@dataclasses.dataclass(frozen=True)
class DivisionConfig:
    """Precision dial per paper eq. 17: (n_iters, precision_bits) -> segments."""

    mode: str = "taylor"
    precision_bits: int = 24      # f32 mantissa target; bf16 would need only 8
    n_iters: int = 2              # paper: n=5 @ 53 bits; n=2 suffices @ 24 bits
    schedule: str = "factored"    # 'paper' | 'factored'
    rsqrt_newton: int = 2
    rsqrt_segments: int = 16
    # Subnormal policy of the jnp twins: "gradual" (default) is exact IEEE
    # gradual underflow via the bit-level datapath (core/fpparts.py);
    # "ftz" keeps the fused kernels' hardware flush contract. The Pallas,
    # ILM, and exact modes always deliver FTZ on this backend — see
    # :func:`effective_underflow`.
    underflow: str = "gradual"

    def __post_init__(self):
        if self.mode not in MODES:
            raise ValueError(f"mode {self.mode!r} not in {MODES}")
        if self.underflow not in UNDERFLOW_POLICIES:
            raise ValueError(
                f"underflow {self.underflow!r} not in {UNDERFLOW_POLICIES}")

    @property
    def table(self):
        return compute_segments(self.n_iters, self.precision_bits)

    @property
    def rtable(self):
        return rsqrt_seed_table(self.rsqrt_segments)

    @property
    def gs_iters(self) -> int:
        """Goldschmidt iterations matching this n_iters' covered-term count."""
        return goldschmidt.iters_for_terms(self.n_iters)


EXACT = DivisionConfig(mode="exact")
TAYLOR = DivisionConfig(mode="taylor")


def effective_underflow(cfg: DivisionConfig) -> str:
    """The subnormal policy a config actually delivers.

    Only the pure-jnp twins honor ``cfg.underflow``: the fused Pallas
    kernels flush by design (the hardware contract), the ILM emulation
    keeps its bit-faithful legacy datapath, and mode="exact" inherits the
    backend's behavior — FTZ/DAZ on this CPU backend, so it is reported
    (and conformance-masked) conservatively as "ftz".
    """
    return cfg.underflow if cfg.mode in ("taylor", "goldschmidt") else "ftz"


def recip(x, cfg: DivisionConfig = TAYLOR):

    if cfg.mode == "exact":
        return 1.0 / x
    if cfg.mode in ("taylor", "taylor_pallas"):
        if cfg.mode == "taylor_pallas":
            from repro.kernels import ops as kops

            if kops.pallas_applicable(x):
                return kops.tsdiv_recip(x, n_iters=cfg.n_iters,
                                        precision_bits=cfg.precision_bits,
                                        schedule=cfg.schedule)
        return taylor.reciprocal(x, cfg.table, schedule=cfg.schedule,
                                 underflow=effective_underflow(cfg))
    if cfg.mode in ("goldschmidt", "goldschmidt_pallas"):
        if cfg.mode == "goldschmidt_pallas":
            from repro.kernels import ops as kops

            if kops.pallas_applicable(x):
                return kops.tsdiv_recip(x, n_iters=cfg.n_iters,
                                        precision_bits=cfg.precision_bits,
                                        schedule="goldschmidt")
        return goldschmidt.reciprocal(x, cfg.table, iters=cfg.gs_iters,
                                      underflow=effective_underflow(cfg))
    if cfg.mode == "ilm":
        return _recip_ilm_jnp(x, cfg)
    raise ValueError(cfg.mode)


def div(a, b, cfg: DivisionConfig = TAYLOR):
    """a/b through the exponent-separated datapath (never a * recip(b)).

    Every approximate mode refines the mantissa pair in [1, 2) and applies
    the exponent difference once at the end, so the quotient is accurate
    whenever a/b is representable — even where the intermediate reciprocal
    would under/overflow (a = 2^100, b = 2^127). The Pallas modes dispatch
    to the fused divide kernel (schedule="goldschmidt" runs the joint N/D
    refinement in-kernel); ilm keeps the bit-faithful a * recip(b)
    emulation, whose under/overflow is part of what it emulates.
    """
    if cfg.mode == "exact":
        return a / b
    if cfg.mode == "ilm":
        import jax.numpy as jnp

        from . import fpparts

        aj, bj = jnp.broadcast_arrays(jnp.asarray(a), jnp.asarray(b))
        q = aj * recip(bj, cfg)
        # The special-value logic sits outside the mantissa datapath even in
        # the ILM unit: the composed multiply turns inf * (recip-underflow-
        # to-0) into nan where IEEE wants inf.
        s = fpparts.sign_product(jnp, aj, bj)
        return fpparts.div_edges(jnp, q, aj, bj, jnp.abs(aj), jnp.abs(bj), s)
    if cfg.mode in ("taylor_pallas", "goldschmidt_pallas"):
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        aj, bj = jnp.broadcast_arrays(jnp.asarray(a), jnp.asarray(b))
        # Promote mixed operands up front (as a * recip(b) would have): the
        # kernel wrapper returns its first argument's dtype.
        ct = jnp.promote_types(aj.dtype, bj.dtype)
        aj, bj = aj.astype(ct), bj.astype(ct)
        if kops.pallas_applicable(aj) and kops.pallas_applicable(bj):
            sched = (cfg.schedule if cfg.mode == "taylor_pallas"
                     else "goldschmidt")
            return kops.tsdiv_divide(aj, bj, n_iters=cfg.n_iters,
                                     precision_bits=cfg.precision_bits,
                                     schedule=sched)
    if cfg.mode in ("goldschmidt", "goldschmidt_pallas"):
        # Goldschmidt's hallmark: the numerator rides the F-multiplies.
        return goldschmidt.divide(a, b, cfg.table, iters=cfg.gs_iters,
                                  underflow=effective_underflow(cfg))
    return taylor.divide(a, b, cfg.table, schedule=cfg.schedule,
                         underflow=effective_underflow(cfg))


def rsqrt(x, cfg: DivisionConfig = TAYLOR):
    """1/sqrt(x) through the mode the config names — no silent fallthrough.

    exact -> XLA ``lax.rsqrt``; taylor/goldschmidt -> the shared jnp
    PWL-seed + Newton datapath (rsqrt's accuracy dial is ``rsqrt_newton``,
    not the series depth, so the two jnp algorithm families deliberately
    share one body — see ROADMAP); taylor_pallas/goldschmidt_pallas -> the
    fused full-edge rsqrt kernel (``kernels.ops.tsdiv_rsqrt``, FTZ) with
    the jnp twin as the documented fallback for non-launchable operands
    (empty arrays, unsupported dtypes); ilm -> Newton iterations with every
    multiply through the 16-bit ILM (tests/benchmarks only, ~12-bit).
    """
    import jax

    if cfg.mode == "exact":
        return jax.lax.rsqrt(x)
    if cfg.mode in ("taylor_pallas", "goldschmidt_pallas"):
        import jax.numpy as jnp

        from repro.kernels import ops as kops

        if kops.pallas_applicable(jnp.asarray(x)):
            return kops.tsdiv_rsqrt(jnp.asarray(x),
                                    newton_iters=cfg.rsqrt_newton,
                                    n_segments=cfg.rsqrt_segments)
    if cfg.mode == "ilm":
        return _rsqrt_ilm_jnp(x, cfg)
    return taylor.rsqrt(x, cfg.rtable, newton_iters=cfg.rsqrt_newton,
                        underflow=effective_underflow(cfg))


def softmax(x, axis: int = -1, cfg: DivisionConfig = TAYLOR, where=None):
    """Numerically-stable softmax whose 1/sum goes through the division unit.

    Mode-faithful dispatch: the Pallas modes route to the fused softmax
    kernel (``kernels.ops.softmax`` — max/exp/sum/scale in one VMEM pass,
    schedule="goldschmidt" for mode="goldschmidt_pallas") whenever the
    operand is kernel-launchable, with the jnp twin below as the documented
    fallback for non-launchable operands (empty arrays, dtypes the kernels
    don't take). The fallback twin still routes its 1/sum through
    :func:`recip` under the same config — its f32 intermediates are
    launchable, so a Pallas config reaches the fused *scalar* unit even
    when the fused *consumer* kernel cannot run; both paths deliver the
    Pallas modes' FTZ policy (see :func:`effective_underflow`).
    Fully-masked rows (``where`` all-False, or every logit -inf) return
    zeros in every mode — never 0 * recip(0) = nan (nor 0/0 in exact
    mode).
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(x)
    if x.ndim == 0:
        # A single logit normalizes to 1 — jnp.max over axis=-1 of a scalar
        # would raise instead of degrading gracefully.
        return jnp.ones_like(x)
    if x.shape[axis] == 0:
        return x                     # no logits: empty in, empty out
    if cfg.mode in ("taylor_pallas", "goldschmidt_pallas"):
        from repro.kernels import ops as kops

        if kops.pallas_applicable(x):
            ax = axis % x.ndim
            xm = x if where is None else jnp.where(where, x, -jnp.inf)
            if ax != x.ndim - 1:
                xm = jnp.moveaxis(xm, ax, -1)
            sched = (cfg.schedule if cfg.mode == "taylor_pallas"
                     else "goldschmidt")
            out = kops.softmax(xm, n_iters=cfg.n_iters,
                               precision_bits=cfg.precision_bits,
                               schedule=sched)
            if ax != x.ndim - 1:
                out = jnp.moveaxis(out, -1, ax)
            return out
    # f32 compute with the input dtype back out, like every datapath in
    # core/ (and like the fused kernel): a bf16 exp would round the shifted
    # logit to 8 bits and amplify by |arg| — tens of output ULPs on
    # wide-dynamic-range rows.
    xf = x.astype(jnp.float32)
    xmax = jnp.max(xf, axis=axis, keepdims=True, where=where,
                   initial=-jnp.inf if where is not None else None)
    xmax = jnp.where(jnp.isfinite(xmax), xmax, 0.0)
    ex = jnp.exp(xf - jax.lax.stop_gradient(xmax))
    if where is not None:
        ex = jnp.where(where, ex, 0.0)
    s = jnp.sum(ex, axis=axis, keepdims=True)
    # Fully-masked rows have ex == 0 lane-wise, so a divisor of 1 yields the
    # zero row exactly; rows with any surviving logit have s >= 1.
    safe = jnp.where(s == 0, jnp.ones_like(s), s)
    out = ex / safe if cfg.mode == "exact" else ex * recip(safe, cfg)
    return out.astype(x.dtype)


def rmsnorm(x, w, cfg: DivisionConfig = TAYLOR, *, eps: float = 1e-6):
    """RMSNorm over the last dim; the 1/sqrt runs the configured mode.

    The Pallas modes dispatch to the fused kernel (``kernels.ops.rmsnorm``:
    mean-of-squares -> PWL-seeded Newton rsqrt -> scale in one VMEM pass);
    every other mode runs the jnp twin with the rsqrt routed through
    :func:`rsqrt` — so exact/taylor/goldschmidt/ilm all answer to the same
    config knob. When a Pallas config's operand is not kernel-launchable
    (empty, unsupported dtype), the twin's f32 mean-of-squares still
    reaches the fused rsqrt kernel through :func:`rsqrt` — the scalar unit
    stays fused even when the consumer kernel cannot run. f32 compute,
    input dtype back out.
    """
    import jax.numpy as jnp

    x = jnp.asarray(x)
    w = jnp.asarray(w)
    if x.ndim == 0 or x.shape[-1] == 0:
        return x
    if cfg.mode in ("taylor_pallas", "goldschmidt_pallas"):
        from repro.kernels import ops as kops

        if kops.pallas_applicable(x):
            return kops.rmsnorm(x, w, eps=eps,
                                newton_iters=cfg.rsqrt_newton,
                                n_segments=cfg.rsqrt_segments)
    xf = x.astype(jnp.float32)
    ss = jnp.mean(xf * xf, axis=-1, keepdims=True)
    if cfg.mode == "exact":
        import jax

        r = jax.lax.rsqrt(ss + jnp.float32(eps))
    else:
        r = rsqrt(ss + jnp.float32(eps), cfg)
    return (xf * r * w.astype(jnp.float32)).astype(x.dtype)


def attention(q, k, v, cfg: DivisionConfig = TAYLOR, *, causal: bool = True):
    """Scaled dot-product attention with the softmax 1/l through the unit.

    q/k/v: (..., S, hd). The Pallas modes dispatch to the fused
    flash-attention kernel (online softmax, Dao et al., with the final 1/l
    normalization in the paper's division unit; schedule="goldschmidt" for
    mode="goldschmidt_pallas"); every other mode runs the jnp twin whose
    row softmax is :func:`softmax` under the same config — one knob for
    every algorithm family (for a Pallas config whose q/k/v are not
    kernel-launchable, the twin's f32 score softmax re-dispatches and
    reaches the fused softmax kernel). Ragged sequence lengths are handled
    by the kernel wrapper (pad-and-mask).
    """
    import jax.numpy as jnp

    q, k, v = jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)
    if cfg.mode in ("taylor_pallas", "goldschmidt_pallas"):
        from repro.kernels import ops as kops

        if (kops.pallas_applicable(q) and kops.pallas_applicable(k)
                and kops.pallas_applicable(v)):
            sched = (cfg.schedule if cfg.mode == "taylor_pallas"
                     else "goldschmidt")
            return kops.flash_attention(q, k, v, causal=causal,
                                        n_iters=cfg.n_iters,
                                        precision_bits=cfg.precision_bits,
                                        schedule=sched)
    # One causal-mask sentinel for the twin and the fused kernel: parity
    # between the two is a gated metric, so the constant must not fork.
    from repro.kernels.flash_attention import NEG_INF

    hd = q.shape[-1]
    s = jnp.einsum("...qh,...kh->...qk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * jnp.float32(1.0 / np.sqrt(hd))
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, jnp.float32(NEG_INF))
    p = softmax(s, -1, cfg)
    return jnp.einsum("...qk,...kh->...qh", p,
                      v.astype(jnp.float32)).astype(q.dtype)


def _ilm_fpmul(mant_bits: int = 12, iters: int = 12):
    """Float multiply with the mantissa product through the 16-bit jnp ILM.

    Mantissas are quantized to ``mant_bits`` so ILM products fit uint32
    lanes; the result carries ~12-bit precision — the "programmable
    accuracy" end of the paper's dial. Shared by the ILM reciprocal and
    rsqrt emulations (tests/benchmarks only).
    """
    import jax.numpy as jnp

    from . import ilm as ilm_mod

    def fpmul(a, b):
        fa, ea = jnp.frexp(jnp.abs(a))
        fb, eb = jnp.frexp(jnp.abs(b))
        scale = 1 << (mant_bits - 1)
        ma = jnp.round(fa * 2 * scale).astype(jnp.uint32)
        mb = jnp.round(fb * 2 * scale).astype(jnp.uint32)
        p = ilm_mod.ilm_mul(ma, mb, iters).astype(jnp.float32)
        r = jnp.ldexp(p / (4.0 * scale * scale), (ea - 1) + (eb - 1) + 2)
        return r * jnp.sign(a) * jnp.sign(b)

    return fpmul


def _recip_ilm_jnp(x, cfg: DivisionConfig):
    """Reciprocal with every multiply routed through the 16-bit jnp ILM."""
    import jax.numpy as jnp

    from . import powering

    table = compute_segments(min(cfg.n_iters, 5), min(cfg.precision_bits, 12))
    fpmul = _ilm_fpmul()

    xf = x.astype(jnp.float32)
    frac, e = jnp.frexp(jnp.abs(xf))
    man = frac * 2.0
    inner = jnp.asarray(table.inner_boundaries, jnp.float32)
    idx = jnp.sum((man[..., None] >= inner).astype(jnp.int32), axis=-1)
    y0 = (jnp.take(jnp.asarray(table.slopes, jnp.float32), idx) * man
          + jnp.take(jnp.asarray(table.intercepts, jnp.float32), idx))
    m = 1.0 - fpmul(man, y0)
    n = table.n_iters
    powers = powering.eval_powers(m, n, mul=fpmul, square=lambda a: fpmul(a, a))
    acc = jnp.ones_like(m) + m
    for k in range(2, n + 1):
        acc = acc + powers[k]
    rman = fpmul(y0, acc)
    r = jnp.ldexp(rman, 1 - e) * jnp.sign(xf)
    # Hardware edge semantics, same as every other mode: +-0 -> +-inf
    # (inf * sign(0) would be nan), +-inf -> +-0, nan -> nan.
    r = jnp.where(xf == 0, jnp.copysign(jnp.float32(np.inf), xf), r)
    r = jnp.where(jnp.isinf(xf), jnp.copysign(jnp.float32(0.0), xf), r)
    r = jnp.where(jnp.isnan(xf), jnp.float32(np.nan), r)
    r = taylor.attach_grad(r, [(xf, -r * r)])
    return r.astype(x.dtype)


def _rsqrt_ilm_jnp(x, cfg: DivisionConfig):
    """rsqrt with every Newton multiply through the 16-bit jnp ILM.

    PWL chord seed on the parity-folded mantissa (same ROM as the jnp
    twins, via ``cfg.rtable``), then ``cfg.rsqrt_newton`` Newton steps whose
    y*y, u*y^2 and correction products all run the ILM — the ~12-bit end of
    the dial, the explicit implementation the dispatch used to silently
    replace with the Taylor datapath. FTZ semantics (subnormal operands are
    the zero class, like every ILM/kernel path), IEEE edges as elsewhere:
    ±0 -> ±inf, +inf -> +0, x < 0 and nan -> nan. Gradients via the shared
    custom_jvp rule (fpparts.jnp_rsqrt). Tests/benchmarks only.
    """
    import jax.numpy as jnp

    from . import fpparts

    table = cfg.rtable
    fpmul = _ilm_fpmul()

    def impl(xp, xf):
        ax = xp.abs(xf)
        frac, e = xp.frexp(ax)          # ax = frac * 2^e, frac in [0.5, 1)
        s = e >> 1
        u = xp.ldexp(frac, e - 2 * s)   # in [0.5, 2)
        inner = xp.asarray(table.inner_boundaries, xp.float32)
        idx = xp.sum((u[..., None] >= inner).astype(jnp.int32), axis=-1)
        y = (xp.take(xp.asarray(table.slopes, xp.float32), idx) * u
             + xp.take(xp.asarray(table.intercepts, xp.float32), idx))
        for _ in range(cfg.rsqrt_newton):    # honor the dial exactly, like
            t = fpmul(u, fpmul(y, y))        # every other rsqrt datapath
            y = fpmul(y, 1.5 - 0.5 * t)
        r = xp.ldexp(y, -s)
        # FTZ zero class (zeros and subnormal magnitudes) -> signed inf.
        tiny = jnp.float32(2.0 ** -126)
        r = xp.where(ax < tiny, xp.copysign(jnp.float32(np.inf), xf), r)
        r = xp.where(xp.isinf(xf) & (xf > 0), jnp.float32(0.0), r)
        neg = (xf < 0) & ~(ax < tiny)
        return xp.where(neg | xp.isnan(xf), jnp.float32(np.nan), r)

    return fpparts.jnp_rsqrt(x, impl)
