"""Goldschmidt division sharing the paper's PWL seed (the canonical rival).

Goldschmidt's algorithm ("Implementation of Goldschmidt's Algorithm with
hardware reduction", arXiv:1909.10154) refines numerator and denominator
jointly:

    F_k = 2 - D_k,   N_{k+1} = N_k * F_k,   D_{k+1} = D_k * F_k

so D -> 1 quadratically and N -> a/b. It shares the seed + multiply structure
of the paper's Taylor unit exactly: with y0 the PWL seed on the denominator
mantissa and m = 1 - b*y0 the seed residual, D_k = 1 - m^(2^(k-1)) and
F_k = 1 + m^(2^(k-1)) — Goldschmidt *is* the factored Taylor product
prod (1 + m^(2^i)) evaluated by a self-correcting recurrence instead of
explicit squarings. j iterations cover 2^j series terms.

This implementation uses the residual-register ("hardware reduction") form:
instead of materializing D and computing F = 2 - D (which truncates the
residual to the bits representable next to 1), it keeps the residual r at its
own exponent and fuses each F-multiply as N + N*r. The first residual comes
from :func:`repro.core.taylor.exact_residual` (full-width seed product), so
the f32 path lands within 1 ulp of the exact quotient. Seed tables are the
paper's (:func:`repro.core.seeds.compute_segments`) — one ROM serves both
algorithms.

Twins as elsewhere in core/: ``*_np`` f64 numpy oracle, bare names jnp/f32.
"""
from __future__ import annotations

import math

import numpy as np

from . import fpparts
from .seeds import SeedTable, compute_segments
from .taylor import exact_residual, seed_eval

__all__ = [
    "iters_for_terms", "reciprocal", "reciprocal_np", "divide", "divide_np",
]


def iters_for_terms(n_terms: int) -> int:
    """Goldschmidt iterations covering >= n_terms+1 series terms (2^j >= n+1).

    Puts mode="goldschmidt" on the same n_iters dial as the Taylor schedules:
    DivisionConfig(n_iters=n) -> iters_for_terms(n) Goldschmidt iterations
    match the factored schedule's covered-term count exactly.
    """
    return max(1, math.ceil(math.log2(n_terms + 1)))


def _refine(num0, man_b, y0, iters: int, with_recip: bool = False):
    """Joint refinement: N starts at num0*y0-ish, residual r = 1 - man_b*y0.

    with_recip additionally rides a 1/man_b accumulator on the same residual
    sequence (one extra FMA per iteration) — the divide path needs it for
    the analytic gradient dq/db = -q/b. Pure operator arithmetic: serves
    numpy, jnp, and the fused Pallas kernel body alike.
    """
    r = exact_residual(man_b, y0)
    n = num0
    y = y0
    for _ in range(iters):
        n = n + n * r       # N * F with F = 1 + r, low bits of r intact
        if with_recip:
            y = y + y * r
        r = r * r           # next residual: 1 - D*F = r^2 exactly
    return (n, y) if with_recip else n


def _reciprocal_impl(xp, x, table: SeedTable, iters: int,
                     underflow: str = "gradual"):
    if xp is not np:
        def mantissa_fn(man):
            y0 = seed_eval(xp, man, table)
            return _refine(y0, man, y0, iters)

        return fpparts.bit_reciprocal(x, mantissa_fn, underflow)
    sign = xp.sign(x)
    ax = xp.abs(x)
    frac, e = xp.frexp(ax)          # ax = frac * 2^e, frac in [0.5, 1)
    man = frac * 2.0                # in [1, 2)
    y0 = seed_eval(xp, man, table)
    rman = _refine(y0, man, y0, iters)          # in (0.5, 1]
    r = xp.ldexp(rman, 1 - e) * sign
    # Same hardware edge semantics as the Taylor unit.
    r = xp.where(ax == 0, xp.copysign(xp.asarray(np.inf, r.dtype), x), r)
    r = xp.where(xp.isinf(ax), xp.copysign(xp.asarray(0.0, r.dtype), x), r)
    r = xp.where(xp.isnan(x), xp.asarray(np.nan, r.dtype), r)
    return r


def _divide_impl(xp, a, b, table: SeedTable, iters: int,
                 underflow: str = "gradual"):
    """Exponent-separated joint N/D divide via the shared fpparts layer.

    numpy keeps the frexp round-trip (f64 oracle); the jnp f32 path runs the
    shared bit-level skeleton (fpparts.bit_divide) with the joint N/D
    recurrence as the mantissa refinement.
    """
    if xp is not np:
        def mantissa_fn(man_a, man_b):
            y0 = seed_eval(xp, man_b, table)
            return _refine(man_a * y0, man_b, y0, iters, with_recip=True)

        return fpparts.bit_divide(a, b, mantissa_fn, underflow)
    s, aa, ab, man_a, man_b, ea, eb = fpparts.decompose_div(xp, a, b)
    y0 = seed_eval(xp, man_b, table)
    q_man, rb_man = _refine(man_a * y0, man_b, y0, iters,
                            with_recip=True)        # q_man in (0.5, 2)
    rb = fpparts.recombine_recip(xp, rb_man, eb, b)  # ~1/b, for the VJP
    q = fpparts.recombine_div(xp, q_man, ea - eb, s)  # ea-eb spans ~[-253, 253]
    return fpparts.div_edges(xp, q, a, b, aa, ab, s), rb


# ---------------------------------------------------------------- numpy oracle

def reciprocal_np(x, table: SeedTable | None = None, *, iters: int = 2) -> np.ndarray:
    table = table or compute_segments(5, 53)
    return _reciprocal_impl(np, np.asarray(x, np.float64), table, iters)


def divide_np(a, b, table: SeedTable | None = None, *, iters: int = 2) -> np.ndarray:
    table = table or compute_segments(5, 53)
    q, _ = _divide_impl(np, np.asarray(a, np.float64),
                        np.asarray(b, np.float64), table, iters)
    return q


# ------------------------------------------------------------------- jnp path

def reciprocal(x, table: SeedTable | None = None, *, iters: int = 2,
               underflow: str = "gradual"):
    """Goldschmidt reciprocal in JAX. f32 compute; bf16/f16 pass through f32."""
    table = table or compute_segments(2, 24)
    return fpparts.jnp_reciprocal(
        x, lambda xp, xf: _reciprocal_impl(xp, xf, table, iters, underflow))


def divide(a, b, table: SeedTable | None = None, *, iters: int = 2,
           underflow: str = "gradual"):
    """Goldschmidt a/b with joint N/D refinement (not a*recip(b))."""
    table = table or compute_segments(2, 24)
    return fpparts.jnp_divide(
        a, b, lambda xp, af, bf: _divide_impl(xp, af, bf, table, iters,
                                              underflow))
