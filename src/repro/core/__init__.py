"""Core: the paper's contribution — Taylor-series division with PWL seeds and
the Iterative Logarithmic Multiplier — as composable JAX modules."""
from . import ilm, powering, seeds, taylor
from .division_modes import EXACT, TAYLOR, DivisionConfig, div, recip, rsqrt, softmax
from .seeds import SeedTable, compute_segments

__all__ = [
    "ilm", "powering", "seeds", "taylor",
    "DivisionConfig", "EXACT", "TAYLOR",
    "div", "recip", "rsqrt", "softmax",
    "SeedTable", "compute_segments",
]
