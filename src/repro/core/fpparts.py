"""Shared sign/exponent/mantissa bookkeeping for the divide datapath.

Real hardware dividers (the source paper's unit, and the Goldschmidt units of
arXiv:1909.10154) never divide full floats: they xor the signs, subtract the
exponents, and refine a *mantissa pair in [1, 2)*, recombining at the very
end. Composing ``a * recip(b)`` instead materializes an intermediate
reciprocal that under/overflows even when ``a/b`` is representable (e.g.
a = 2^100, b = 2^127: 1/b is subnormal, but a/b = 2^-27 is a perfectly
normal float). This module is that hardware bookkeeping, factored once:

  * :func:`decompose_div`  — sign product, |a|/|b|, mantissas in [1, 2) via a
    single ``frexp`` per operand, and the unbiased exponents;
  * :func:`recombine_div`  — one round-trip back through ``ldexp``, split in
    two steps so the internal 2^k factor never overflows;
  * :func:`div_edges`      — the IEEE/hardware special-value contract
    (±0, ±inf, nan sign rules) applied after the mantissa math;
  * :func:`two_product`    — Dekker/Veltkamp error-free multiply, the
    building block for compensated residuals;
  * :func:`refine_quotient` — Markstein-style correcting final multiply:
    the hardware unit's final multiplier produces the full 2p-bit product
    and rounds once, which p-bit float emulation recovers by folding the
    exact remainder ``a - q0*b`` back through the reciprocal.

Everything is pure operator arithmetic parameterized by the array module
``xp``, so one body serves the numpy f64 oracles, the jnp f32 path, and the
Pallas kernel bodies alike.

Since PR 4 the jnp f32 twins no longer round-trip through ``frexp``/``ldexp``
at all: :func:`split_f32` / :func:`repack_f32` do the sign/exponent/mantissa
bookkeeping on the raw int32 bit patterns — the same field extraction as the
fused kernels' ``divide_f32_bits`` (kernels/common.py imports the field
masks from here) — with explicit subnormal normalization on the way in and a
round-to-nearest-even integer repack on the way out. Two reasons:

  * XLA's ``frexp`` mis-scales subnormal operands (``frexp(2^-127)`` ->
    ``(0.5, -149)``), so gradual underflow was a degraded, masked class;
  * this CPU backend runs FTZ/DAZ: float multiplies flush subnormal inputs
    *and* outputs, and even float comparisons report subnormals as zero —
    so both classification and the subnormal repack must be pure integer
    bit manipulation to be exact (and deterministic across backends).

The delivered subnormal behavior is a policy knob (``underflow=``):
``"gradual"`` (jnp-twin default) normalizes subnormal operands and rounds
underflowing results into the subnormal range exactly; ``"ftz"`` keeps the
hardware contract of the fused kernels — subnormal operands are zeros,
results that round subnormal flush to signed zero.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "two_product", "sign_product", "decompose_div", "ldexp2", "recombine_div",
    "div_edges", "refine_quotient", "recombine_recip", "jnp_divide",
    "jnp_reciprocal", "jnp_rsqrt", "split_f32", "repack_f32", "bit_divide",
    "bit_reciprocal", "UNDERFLOW_POLICIES",
    "F32_SIGN", "F32_MAG_MASK", "F32_EXP_MASK", "F32_MAN_MASK",
    "F32_ONE_BITS", "F32_IMPLICIT",
]

# f32 field layout, shared with kernels/common.py (one source of truth for
# the "field-for-field" alignment between the jnp twins and the fused
# kernels' bit-level unpack).
F32_SIGN = np.uint32(0x8000_0000)
F32_MAG_MASK = np.uint32(0x7FFF_FFFF)
F32_EXP_MASK = np.uint32(0x7F80_0000)
F32_MAN_MASK = np.uint32(0x007F_FFFF)
F32_ONE_BITS = np.uint32(0x3F80_0000)
F32_IMPLICIT = np.uint32(0x0080_0000)   # hidden bit / smallest normal's bits

UNDERFLOW_POLICIES = ("gradual", "ftz")


def sign_product(xp, a, b):
    """±1 with the sign of a*b, signed zeros included (the quotient sign)."""
    return (xp.copysign(xp.asarray(1.0, a.dtype), a)
            * xp.copysign(xp.asarray(1.0, b.dtype), b))


def two_product(a, b):
    """Error-free transform of a product: returns (p, e) with a*b == p + e.

    Veltkamp-split both operands with the factor 2^ceil(prec/2) + 1
    (f32 -> 4097, f64 -> 2^27 + 1) and recover the rounding error of the
    p-bit product. Works under FMA contraction too — a contracted
    ``ah*bh - p`` is the exact error term.
    """
    p = a * b
    prec = np.finfo(np.dtype(a.dtype)).nmant + 1
    c = float(2 ** ((prec + 1) // 2) + 1)
    ta = c * a
    ah = ta - (ta - a)
    al = a - ah
    tb = c * b
    bh = tb - (tb - b)
    bl = b - bh
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def decompose_div(xp, a, b):
    """Unpack a divide: sign product, magnitudes, [1,2) mantissas, exponents.

    Returns ``(s, aa, ab, man_a, man_b, ea, eb)`` with |a| = man_a * 2^(ea-1)
    and likewise for b (``frexp`` convention: frac in [0.5, 1), so the [1, 2)
    mantissa carries exponent e-1). Zeros keep a zero mantissa; infs/nans
    pass through frexp and are overridden by :func:`div_edges`.
    """
    s = sign_product(xp, a, b)
    aa, ab = xp.abs(a), xp.abs(b)
    fa, ea = xp.frexp(aa)
    fb, eb = xp.frexp(ab)
    man_a, man_b = fa * 2.0, fb * 2.0               # [1, 2); 0 stays 0
    return s, aa, ab, man_a, man_b, ea, eb


def ldexp2(xp, x, k):
    """ldexp for |k| up to ~2*emax: two steps so the internal 2^k factor
    never overflows even when x * 2^k is representable."""
    h = k // 2
    return xp.ldexp(xp.ldexp(x, h), k - h)


def recombine_div(xp, q_man, de, s):
    """q = q_man * 2^de * s. de = ea - eb spans ~[-2*emax, 2*emax]."""
    return ldexp2(xp, q_man, de) * s


def div_edges(xp, q, a, b, aa, ab, s):
    """IEEE special-value contract for a/b, applied after the mantissa math:

        x/±0 -> ±inf    ±inf/y -> ±inf    x/±inf -> ±0    (sign = s)
        0/0, inf/inf, nan operands -> nan
    """
    inf = xp.asarray(np.inf, q.dtype)
    zero = xp.asarray(0.0, q.dtype)
    nan = xp.asarray(np.nan, q.dtype)
    q = xp.where((ab == 0) & (aa != 0), xp.copysign(inf, s), q)
    q = xp.where(xp.isinf(aa) & ~xp.isinf(ab), xp.copysign(inf, s), q)
    q = xp.where(xp.isinf(ab) & ~xp.isinf(aa), xp.copysign(zero, s), q)
    q = xp.where((aa == 0) & (ab == 0), nan, q)
    q = xp.where(xp.isinf(aa) & xp.isinf(ab), nan, q)
    q = xp.where(xp.isnan(a) | xp.isnan(b), nan, q)
    return q


def recombine_recip(xp, rman, eb, b):
    """~1/b from the refined mantissa reciprocal (feeds the analytic VJP;
    under/overflow here only zeroes a gradient lane, never the primal)."""
    return xp.ldexp(rman, 1 - eb) * xp.sign(b)


def jnp_divide(a, b, impl):
    """Shared jnp wrapper for the exponent-separated divides.

    ``impl(jnp, af, bf) -> (q, rb)`` is the f32 divide body (Taylor or
    Goldschmidt). Handles dtype promotion (mixed bf16/f32 operands promote,
    as the composed ``a * recip(b)`` form did), the f32 compute dance, and
    supplies the analytic derivative dq = rb*da - q*rb*db through a
    ``custom_jvp`` (bitcasts carry zero cotangent, and the arithmetic
    straight-through of ``taylor.attach_grad`` would flush gradual-underflow
    primals on FTZ/DAZ backends — a custom derivative rule leaves the primal
    bits untouched; custom_jvp rather than custom_vjp so forward-mode
    autodiff keeps working, with reverse mode derived by transposing the
    linear tangent map). Edge lanes (q or 1/b non-finite) get zero
    derivative, not nan.
    """
    import jax
    import jax.numpy as jnp

    a, b = jnp.asarray(a), jnp.asarray(b)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    # Broadcast OUTSIDE the custom_jvp boundary: autodiff of the broadcast
    # op itself sum-reduces cotangents back to each operand's shape.
    af, bf = jnp.broadcast_arrays(a.astype(jnp.float32),
                                  b.astype(jnp.float32))

    @jax.custom_jvp
    def _div(af, bf):
        return impl(jnp, af, bf)[0]

    @_div.defjvp
    def _div_jvp(primals, tangents):
        af, bf = primals
        da, db = tangents
        q, rb = impl(jnp, af, bf)
        rbm = jnp.where(jnp.isfinite(rb), rb, 0.0)
        qm = jnp.where(jnp.isfinite(q), q, 0.0)
        return q, rbm * da - qm * rbm * db

    return _div(af, bf).astype(out_dtype)


def jnp_reciprocal(x, impl):
    """Shared jnp wrapper for the bit-level reciprocals.

    ``impl(jnp, xf) -> r`` is the f32 body. Same custom_jvp rationale as
    :func:`jnp_divide`: d(1/x) = -r^2 dx with edge lanes masked to zero,
    and the primal bits pass through untouched (gradual-underflow results
    can be subnormal, which arithmetic straight-through would flush).
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(x)
    out_dtype = x.dtype
    xf = x.astype(jnp.float32)

    @jax.custom_jvp
    def _recip(xf):
        return impl(jnp, xf)

    @_recip.defjvp
    def _recip_jvp(primals, tangents):
        (xf,), (dx,) = primals, tangents
        r = impl(jnp, xf)
        rf = jnp.where(jnp.isfinite(r), r, 0.0)
        return r, -(rf * rf) * dx

    return _recip(xf).astype(out_dtype)


def jnp_rsqrt(x, impl):
    """Shared jnp wrapper for the bit-level rsqrt datapaths.

    ``impl(jnp, xf) -> r`` is the f32 body. Same custom_jvp rationale as
    :func:`jnp_reciprocal` (the arithmetic straight-through of
    ``taylor.attach_grad`` would flush gradual-underflow *primals* on this
    FTZ/DAZ backend — a custom derivative rule leaves the primal bits
    untouched): d(x^-1/2) = -r^3/2 dx. The analytic coefficient itself can
    overflow f32 even where r is finite (r ~ 2^64 for subnormal operands
    gives r^3 ~ 2^192), so non-finite *gradient* lanes are masked to zero
    — the gradient lane degrades, the primal never does.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(x)
    out_dtype = x.dtype
    xf = x.astype(jnp.float32)

    @jax.custom_jvp
    def _rsqrt(xf):
        return impl(jnp, xf)

    @_rsqrt.defjvp
    def _rsqrt_jvp(primals, tangents):
        (xf,), (dx,) = primals, tangents
        r = impl(jnp, xf)
        rf = jnp.where(jnp.isfinite(r), r, 0.0)
        g = jnp.float32(-0.5) * rf * rf * rf
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        return r, g * dx

    return _rsqrt(xf).astype(out_dtype)


# ----------------------------------------------------- bit-level f32 datapath

def split_f32(mag_bits):
    """Exponent/mantissa split of f32 *magnitude bits*, subnormal-exact.

    Returns ``(man, e)`` with ``man`` an f32 in [1, 2) and ``e`` int32 such
    that the magnitude equals ``man * 2^e`` for every finite nonzero input —
    subnormals are normalized (their leading-bit position found via an exact
    int->float convert of the mantissa field, never a float multiply, which
    FTZ/DAZ backends would flush). Zeros give (0.0, -127); infs/nans give
    (1.mantissa, 128) for the caller's edge overrides to discard.
    """
    import jax.numpy as jnp
    from jax import lax

    expf = (mag_bits >> 23).astype(jnp.int32)
    manf = mag_bits & F32_MAN_MASK
    # Subnormal magnitude = manf * 2^-149; int->float conversion of manf is
    # exact (manf < 2^24) and lands in the normal range, so its own biased
    # exponent reveals the leading-bit index L: manf = 1.xxx * 2^L.
    mf = manf.astype(jnp.float32)
    mfbits = lax.bitcast_convert_type(mf, jnp.uint32)
    lead = (mfbits >> 23).astype(jnp.int32) - 127
    is_sub = (expf == 0) & (manf != 0)
    man_bits = jnp.where(is_sub, (mfbits & F32_MAN_MASK) | F32_ONE_BITS,
                         manf | F32_ONE_BITS)
    e = jnp.where(is_sub, lead - 149, expf - 127)
    man = lax.bitcast_convert_type(man_bits, jnp.float32)
    man = jnp.where(mag_bits == 0, jnp.float32(0.0), man)
    e = jnp.where(mag_bits == 0, jnp.int32(-127), e)
    return man, e


def repack_f32(man, e, sign_bits, underflow: str = "gradual"):
    """RNE repack of ``sign * man * 2^e`` into f32 bits.

    ``man`` is a *normal* f32 in (0.5, 4) (a refined mantissa), ``e`` int32.
    Normal-range results are assembled exactly from the fields (bit-identical
    to the old exact ``ldexp`` round-trip); results below the normal range
    are rounded to nearest-even into the subnormal lattice by integer
    shift-and-round — a carry that rounds up to 2^-126 lands in the exponent
    field and correctly yields the smallest normal. ``underflow="ftz"``
    flushes results that are still subnormal *after* rounding to signed zero
    (the fused kernels' hardware contract); overflow saturates to infinity.
    Pure integer arithmetic after the field extraction: immune to runtime
    FTZ/DAZ, identical eager and jit.
    """
    import jax.numpy as jnp
    from jax import lax

    mbits = lax.bitcast_convert_type(man, jnp.uint32)
    me = (mbits >> 23).astype(jnp.int32) - 127          # -1, 0, or +1
    frac = (mbits & F32_MAN_MASK) | F32_IMPLICIT        # 24-bit significand
    et = e + me                                         # |q| = 1.frac * 2^et
    # Subnormal target: shift the 24-bit significand right by sh with RNE.
    # sh >= 25 rounds to zero (frac < 2^24 => frac/2^25 < 0.5); the clip to
    # 31 only keeps the shift well-defined for the lanes `where` discards.
    sh = jnp.clip(-126 - et, 0, 31).astype(jnp.uint32)
    keep = frac >> sh
    low = jnp.left_shift(jnp.uint32(1), sh) - jnp.uint32(1)
    rem = frac & low
    half = (low + jnp.uint32(1)) >> 1                   # 2^(sh-1); 0 at sh=0
    round_up = ((rem > half) | ((rem == half) & ((keep & 1) == 1))) & (sh > 0)
    sub_bits = keep + round_up.astype(jnp.uint32)
    norm_bits = ((et + 127).astype(jnp.uint32) << 23) | (frac & F32_MAN_MASK)
    bits = jnp.where(et >= -126, norm_bits, sub_bits)
    if underflow == "ftz":
        bits = jnp.where(bits < F32_IMPLICIT, jnp.uint32(0), bits)
    bits = jnp.where(et > 127, F32_EXP_MASK, bits)      # overflow -> inf
    return lax.bitcast_convert_type(bits | sign_bits, jnp.float32)


def bit_divide(a, b, mantissa_fn, underflow: str = "gradual"):
    """Bit-level exponent-separated a/b skeleton shared by the jnp twins.

    ``mantissa_fn(man_a, man_b) -> (q_man, rb_man)`` refines the [1, 2)
    mantissa pair (Taylor series + Markstein correction, or the joint N/D
    Goldschmidt recurrence). Classification is pure bit tests — on FTZ/DAZ
    backends float comparisons report subnormals as zero, which would
    misroute the gradual lanes into the x/0 contract. Edge overrides apply
    in the same order as ``kernels.common.divide_f32_bits`` so the
    ``underflow="ftz"`` twin is bit-identical to the fused kernel. Returns
    ``(q, rb)`` with rb ~ 1/b for the analytic VJP.
    """
    import jax.numpy as jnp
    from jax import lax

    abits = lax.bitcast_convert_type(a, jnp.uint32)
    bbits = lax.bitcast_convert_type(b, jnp.uint32)
    mag_a, mag_b = abits & F32_MAG_MASK, bbits & F32_MAG_MASK
    sign_bits = (abits ^ bbits) & F32_SIGN
    if underflow == "ftz":
        # Hardware contract: a zero exponent field (zero or subnormal) is
        # the zero class — same field test as the fused kernels.
        a_zero, b_zero = mag_a < F32_IMPLICIT, mag_b < F32_IMPLICIT
    else:
        a_zero, b_zero = mag_a == 0, mag_b == 0
    a_inf, b_inf = mag_a == F32_EXP_MASK, mag_b == F32_EXP_MASK
    a_nan, b_nan = mag_a > F32_EXP_MASK, mag_b > F32_EXP_MASK
    man_a, ea = split_f32(mag_a)
    man_b, eb = split_f32(mag_b)
    one = jnp.float32(1.0)
    man_a = jnp.where(man_a == 0, one, man_a)   # keep edge lanes finite; the
    man_b = jnp.where(man_b == 0, one, man_b)   # overrides below discard them
    q_man, rb_man = mantissa_fn(man_a, man_b)
    q = repack_f32(q_man, ea - eb, sign_bits, underflow)
    inf_s = lax.bitcast_convert_type(F32_EXP_MASK | sign_bits, jnp.float32)
    zero_s = lax.bitcast_convert_type(sign_bits, jnp.float32)
    nan = jnp.float32(np.nan)
    q = jnp.where(b_zero, inf_s, q)             # x/0   -> signed inf
    q = jnp.where(a_zero, zero_s, q)            # 0/y   -> signed 0
    q = jnp.where(a_inf, inf_s, q)              # inf/y -> signed inf
    q = jnp.where(b_inf, zero_s, q)             # x/inf -> signed 0
    q = jnp.where(a_zero & b_zero, nan, q)      # 0/0
    q = jnp.where(a_inf & b_inf, nan, q)        # inf/inf
    q = jnp.where(a_nan | b_nan, nan, q)
    rb = repack_f32(rb_man, -eb, bbits & F32_SIGN, underflow)
    return q, rb


def bit_reciprocal(x, mantissa_fn, underflow: str = "gradual"):
    """Bit-level 1/x skeleton shared by the jnp twins.

    ``mantissa_fn(man) -> rman`` refines the [1, 2) mantissa reciprocal.
    Same bit-test classification and edge order as
    ``kernels.common.recip_f32_bits``; ``underflow="gradual"`` additionally
    makes subnormal operands exact and rounds subnormal reciprocals (of
    near-maxfloat inputs) instead of flushing.
    """
    import jax.numpy as jnp
    from jax import lax

    bits = lax.bitcast_convert_type(x, jnp.uint32)
    mag = bits & F32_MAG_MASK
    sign_bits = bits & F32_SIGN
    if underflow == "ftz":
        x_zero = mag < F32_IMPLICIT
    else:
        x_zero = mag == 0
    x_inf, x_nan = mag == F32_EXP_MASK, mag > F32_EXP_MASK
    man, e = split_f32(mag)
    man = jnp.where(man == 0, jnp.float32(1.0), man)
    rman = mantissa_fn(man)                             # in (0.5, 1]
    r = repack_f32(rman, -e, sign_bits, underflow)
    inf_s = lax.bitcast_convert_type(F32_EXP_MASK | sign_bits, jnp.float32)
    zero_s = lax.bitcast_convert_type(sign_bits, jnp.float32)
    r = jnp.where(x_zero, inf_s, r)
    r = jnp.where(x_inf, zero_s, r)
    return jnp.where(x_nan, jnp.float32(np.nan), r)


def refine_quotient(q0, man_a, man_b, rman):
    """Markstein correcting step: q = q0 + rman * (man_a - q0*man_b).

    The remainder is computed error-free: two_product gives q0*man_b as
    p + e exactly, and man_a - p is exact by Sterbenz (p lies within a
    factor 2 of man_a since q0 ~ man_a/man_b). With rman accurate to even a
    few thousand ULPs the corrected quotient lands within ~1 ULP of
    man_a/man_b — this is the float emulation of the hardware unit's
    full-width final multiplier (Fig. 7), whose 2p-bit product is rounded
    exactly once.
    """
    p, e = two_product(q0, man_b)
    res = (man_a - p) - e
    return q0 + res * rman
