"""Shared sign/exponent/mantissa bookkeeping for the divide datapath.

Real hardware dividers (the source paper's unit, and the Goldschmidt units of
arXiv:1909.10154) never divide full floats: they xor the signs, subtract the
exponents, and refine a *mantissa pair in [1, 2)*, recombining at the very
end. Composing ``a * recip(b)`` instead materializes an intermediate
reciprocal that under/overflows even when ``a/b`` is representable (e.g.
a = 2^100, b = 2^127: 1/b is subnormal, but a/b = 2^-27 is a perfectly
normal float). This module is that hardware bookkeeping, factored once:

  * :func:`decompose_div`  — sign product, |a|/|b|, mantissas in [1, 2) via a
    single ``frexp`` per operand, and the unbiased exponents;
  * :func:`recombine_div`  — one round-trip back through ``ldexp``, split in
    two steps so the internal 2^k factor never overflows;
  * :func:`div_edges`      — the IEEE/hardware special-value contract
    (±0, ±inf, nan sign rules) applied after the mantissa math;
  * :func:`two_product`    — Dekker/Veltkamp error-free multiply, the
    building block for compensated residuals;
  * :func:`refine_quotient` — Markstein-style correcting final multiply:
    the hardware unit's final multiplier produces the full 2p-bit product
    and rounds once, which p-bit float emulation recovers by folding the
    exact remainder ``a - q0*b`` back through the reciprocal.

Everything is pure operator arithmetic parameterized by the array module
``xp``, so one body serves the numpy f64 oracles, the jnp f32 path, and the
Pallas kernel bodies alike.
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "two_product", "sign_product", "decompose_div", "ldexp2", "recombine_div",
    "div_edges", "refine_quotient", "recombine_recip", "jnp_divide",
]


def sign_product(xp, a, b):
    """±1 with the sign of a*b, signed zeros included (the quotient sign)."""
    return (xp.copysign(xp.asarray(1.0, a.dtype), a)
            * xp.copysign(xp.asarray(1.0, b.dtype), b))


def two_product(a, b):
    """Error-free transform of a product: returns (p, e) with a*b == p + e.

    Veltkamp-split both operands with the factor 2^ceil(prec/2) + 1
    (f32 -> 4097, f64 -> 2^27 + 1) and recover the rounding error of the
    p-bit product. Works under FMA contraction too — a contracted
    ``ah*bh - p`` is the exact error term.
    """
    p = a * b
    prec = np.finfo(np.dtype(a.dtype)).nmant + 1
    c = float(2 ** ((prec + 1) // 2) + 1)
    ta = c * a
    ah = ta - (ta - a)
    al = a - ah
    tb = c * b
    bh = tb - (tb - b)
    bl = b - bh
    e = ((ah * bh - p) + ah * bl + al * bh) + al * bl
    return p, e


def decompose_div(xp, a, b):
    """Unpack a divide: sign product, magnitudes, [1,2) mantissas, exponents.

    Returns ``(s, aa, ab, man_a, man_b, ea, eb)`` with |a| = man_a * 2^(ea-1)
    and likewise for b (``frexp`` convention: frac in [0.5, 1), so the [1, 2)
    mantissa carries exponent e-1). Zeros keep a zero mantissa; infs/nans
    pass through frexp and are overridden by :func:`div_edges`.
    """
    s = sign_product(xp, a, b)
    aa, ab = xp.abs(a), xp.abs(b)
    fa, ea = xp.frexp(aa)
    fb, eb = xp.frexp(ab)
    man_a, man_b = fa * 2.0, fb * 2.0               # [1, 2); 0 stays 0
    return s, aa, ab, man_a, man_b, ea, eb


def ldexp2(xp, x, k):
    """ldexp for |k| up to ~2*emax: two steps so the internal 2^k factor
    never overflows even when x * 2^k is representable."""
    h = k // 2
    return xp.ldexp(xp.ldexp(x, h), k - h)


def recombine_div(xp, q_man, de, s):
    """q = q_man * 2^de * s. de = ea - eb spans ~[-2*emax, 2*emax]."""
    return ldexp2(xp, q_man, de) * s


def div_edges(xp, q, a, b, aa, ab, s):
    """IEEE special-value contract for a/b, applied after the mantissa math:

        x/±0 -> ±inf    ±inf/y -> ±inf    x/±inf -> ±0    (sign = s)
        0/0, inf/inf, nan operands -> nan
    """
    inf = xp.asarray(np.inf, q.dtype)
    zero = xp.asarray(0.0, q.dtype)
    nan = xp.asarray(np.nan, q.dtype)
    q = xp.where((ab == 0) & (aa != 0), xp.copysign(inf, s), q)
    q = xp.where(xp.isinf(aa) & ~xp.isinf(ab), xp.copysign(inf, s), q)
    q = xp.where(xp.isinf(ab) & ~xp.isinf(aa), xp.copysign(zero, s), q)
    q = xp.where((aa == 0) & (ab == 0), nan, q)
    q = xp.where(xp.isinf(aa) & xp.isinf(ab), nan, q)
    q = xp.where(xp.isnan(a) | xp.isnan(b), nan, q)
    return q


def recombine_recip(xp, rman, eb, b):
    """~1/b from the refined mantissa reciprocal (feeds the analytic VJP;
    under/overflow here only zeroes a gradient lane, never the primal)."""
    return xp.ldexp(rman, 1 - eb) * xp.sign(b)


def jnp_divide(a, b, impl):
    """Shared jnp wrapper for the exponent-separated divides.

    ``impl(jnp, af, bf) -> (q, rb)`` is the f32 divide body (Taylor or
    Goldschmidt). Handles dtype promotion (mixed bf16/f32 operands promote,
    as the composed ``a * recip(b)`` form did), the f32 compute dance, and
    attaches the analytic gradient dq = rb*da - q*rb*db (frexp/ldexp carry
    zero cotangent otherwise — see taylor.attach_grad).
    """
    import jax.numpy as jnp

    from .taylor import attach_grad

    a, b = jnp.asarray(a), jnp.asarray(b)
    out_dtype = jnp.promote_types(a.dtype, b.dtype)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    q, rb = impl(jnp, af, bf)
    q = attach_grad(q, [(af, rb), (bf, -q * rb)])
    return q.astype(out_dtype)


def refine_quotient(q0, man_a, man_b, rman):
    """Markstein correcting step: q = q0 + rman * (man_a - q0*man_b).

    The remainder is computed error-free: two_product gives q0*man_b as
    p + e exactly, and man_a - p is exact by Sterbenz (p lies within a
    factor 2 of man_a since q0 ~ man_a/man_b). With rman accurate to even a
    few thousand ULPs the corrected quotient lands within ~1 ULP of
    man_a/man_b — this is the float emulation of the hardware unit's
    full-width final multiplier (Fig. 7), whose 2p-bit product is rounded
    exactly once.
    """
    p, e = two_product(q0, man_b)
    res = (man_a - p) - e
    return q0 + res * rman
