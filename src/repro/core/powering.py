"""Powering unit (paper §6) and squaring-unit hardware model (paper §5).

The powering unit computes x^2 .. x^n using the "maximize squaring" heuristic:
  cycle 0:  x^2 by the squaring unit (cache k = priority-encoder(x) and the
            LOD residue x - 2^k for reuse in every later multiply-by-x)
  cycle c:  odd power  x^(2c+1) = x * x^(2c)      (multiplier, cached-x side)
            even power x^(2c+2) = (x^(c+1))^2     (squaring unit)
two new Taylor terms per cycle (paper §6 step 6).

``hw_cost`` reproduces the §5 claim (squaring unit < 50% of the multiplier's
hardware) as a component-count model taken from the paper's Fig. 4 vs Fig. 5
discussion: the multiplier duplicates the priority encoder, LOD, shifter and
adder to parallelize the two operands and needs a decoder for 2^(k1+k2); the
squarer needs one of each, reuses the adder/shifter across stages, and writes
4^k as (100)_2 << k with no decoder.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Tuple

__all__ = ["schedule", "eval_powers", "op_counts", "hw_cost", "HwCost"]

Op = Tuple[str, Any, int]  # (kind, operand(s), result power)


def schedule(n: int) -> List[Op]:
    """Paper §6 op schedule producing x^2..x^n. ('square', src, dst) | ('mul', (1, src), dst)."""
    if n < 2:
        return []
    ops: List[Op] = [("square", 1, 2)]
    c = 1
    while True:
        odd, even = 2 * c + 1, 2 * c + 2
        if odd > n and even > n:
            break
        if odd <= n:
            ops.append(("mul", (1, odd - 1), odd))
        if even <= n:
            ops.append(("square", even // 2, even))
        c += 1
    return ops


def eval_powers(x, n: int, *, mul: Callable, square: Callable) -> Dict[int, Any]:
    """Execute the §6 schedule with the given multiplier/squarer (exact or ILM)."""
    powers: Dict[int, Any] = {1: x}
    for kind, src, dst in schedule(n):
        if kind == "square":
            powers[dst] = square(powers[src])
        else:
            a, b = src
            powers[dst] = mul(powers[a], powers[b])
    return powers


def op_counts(n: int, sched: str = "paper") -> Dict[str, int]:
    """Multiplies/squares/cycles needed to evaluate sum_{k<=n} m^k."""
    import math

    if sched == "paper":
        ops = schedule(n)
        sq = sum(1 for o in ops if o[0] == "square")
        mu = sum(1 for o in ops if o[0] == "mul")
        # one odd+even pair per cycle after the initial square (paper §6)
        cycles = 1 + max(0, (n - 2 + 1) // 2) if n >= 2 else 0
        return {"mul": mu, "square": sq, "add": max(0, n), "cycles": cycles,
                "terms": n + 1}
    if sched == "factored":
        if n <= 0:
            return {"mul": 0, "square": 0, "add": 0, "cycles": 0, "terms": 1}
        j = max(1, math.ceil(math.log2(n + 1)))
        # t starts at m^2 (1 square); each extra factor costs 1 square + 1 mul.
        return {"mul": j - 1, "square": j - 1, "add": j, "cycles": j,
                "terms": 2**j}
    raise ValueError(sched)


@dataclass(frozen=True)
class HwCost:
    """Component counts. Weights are relative area units (encoder-heavy blocks
    dominate; exact weights don't change the <50% conclusion, see benchmark)."""

    priority_encoder: int
    lod: int
    barrel_shifter: int
    adder: int
    decoder: int
    weights: Dict[str, float] = field(default_factory=lambda: {
        "priority_encoder": 3.0, "lod": 3.0, "barrel_shifter": 2.0,
        "adder": 1.5, "decoder": 1.0,
    })

    def area(self) -> float:
        return (self.priority_encoder * self.weights["priority_encoder"]
                + self.lod * self.weights["lod"]
                + self.barrel_shifter * self.weights["barrel_shifter"]
                + self.adder * self.weights["adder"]
                + self.decoder * self.weights["decoder"])


def hw_cost() -> Dict[str, Any]:
    """Paper §5: squaring unit vs iterative-log multiplier component counts."""
    multiplier = HwCost(priority_encoder=2, lod=2, barrel_shifter=2, adder=2, decoder=1)
    squarer = HwCost(priority_encoder=1, lod=1, barrel_shifter=1, adder=1, decoder=0)
    return {
        "multiplier": multiplier,
        "squarer": squarer,
        "area_ratio": squarer.area() / multiplier.area(),
        "unit_ratio": (squarer.priority_encoder + squarer.lod + squarer.barrel_shifter
                       + squarer.adder + squarer.decoder)
        / (multiplier.priority_encoder + multiplier.lod + multiplier.barrel_shifter
           + multiplier.adder + multiplier.decoder),
    }
