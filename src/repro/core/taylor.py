"""Taylor-series reciprocal / divide / rsqrt (paper §2-3 + §6 schedules).

Two twin implementations share one body, parameterized by the array module:

  * ``reciprocal_np`` — float64 numpy oracle. Used to validate the paper's
    53-bit claims (f64 precision without flipping jax_enable_x64).
  * ``reciprocal`` — jnp, f32 compute (bf16 in/out supported). This is the
    production path that models call through ``core.division_modes``.

Evaluation schedules for  acc = sum_{k=0}^{n} m^k  (m = 1 - x*y0):

  * ``paper``    — §6 powering unit: per cycle one odd power by multiply
                   (x * x^k) and one even power by square ((x^{k/2+1})^2).
                   Faithful term count: exactly n+1 terms.
  * ``factored`` — beyond-paper:  prod_{i<j} (1 + m^(2^i)) = sum_{k<2^j} m^k
                   with j = ceil(log2(n+1)). Squarings only, log-depth; covers
                   *at least* n+1 terms (never fewer — strictly more accurate
                   at equal-or-lower op count). TPU-preferred.
"""
from __future__ import annotations

import math
from typing import Any

import numpy as np

from .seeds import SeedTable, compute_segments, rsqrt_seed_table
from . import fpparts, powering

__all__ = [
    "reciprocal", "reciprocal_np", "divide", "divide_np", "rsqrt", "rsqrt_np",
    "default_table", "exact_residual", "series_sum", "seed_eval",
    "divide_mantissa", "attach_grad",
]


def default_table(precision_bits: int = 24, n_iters: int = 2) -> SeedTable:
    """Default seed table: (n, precision) -> segments. f32 default: n=2, 24 bits."""
    return compute_segments(n_iters, precision_bits)


def exact_residual(man, y0):
    """m = 1 - man*y0 at full product width (Dekker two-product).

    The hardware unit subtracts the seed multiplier's *untruncated* 2p-bit
    output from 1, so the residual that drives the series carries no rounding.
    Emulating in p-bit float needs an error-free transform: Veltkamp-split
    both operands, recover the rounding error e of the p-bit product, and
    fold it into the (Sterbenz-exact) subtraction. Works under FMA
    contraction too — a contracted ``hi*hi - p`` is the exact error term.
    Pure operator arithmetic, so one body serves numpy and jnp (no xp
    parameter, unlike its siblings).
    """
    p, e = fpparts.two_product(man, y0)                 # man*y0 == p + e exactly
    return (1.0 - p) - e


def series_sum(xp, m, n: int, schedule: str):
    """s = sum_{k=1}^{n'} m^k with n' >= n, per the requested schedule.

    Returned *without* the leading 1 so callers can form y0 + y0*s — adding 1
    to a ~2^-p/(n+1) sized sum would truncate its low bits before the final
    multiply and cost ~1 ulp of the result.
    """
    if n <= 0:
        return xp.zeros_like(m)
    if schedule == "factored":
        j = max(1, math.ceil(math.log2(n + 1)))
        s = m
        t = m * m
        for _ in range(j - 1):
            s = s + t * (1.0 + s)     # (1+s)(1+t) = 1 + (s + t*(1+s))
            t = t * t
        return s
    if schedule == "paper":
        powers = powering.eval_powers(m, n, mul=lambda a, b: a * b, square=lambda a: a * a)
        s = m
        for k in range(2, n + 1):
            s = s + powers[k]
        return s
    raise ValueError(f"unknown schedule {schedule!r}")


def seed_eval(xp, man, table: SeedTable):
    """PWL seed y0(man): compare-sum segment lookup + per-segment FMA.

    Shared by the Taylor and Goldschmidt paths (one seed ROM, two
    refinement algorithms)."""
    inner = table.inner_boundaries.astype(man.dtype)
    slopes = table.slopes.astype(man.dtype)
    intercepts = table.intercepts.astype(man.dtype)
    if len(inner):
        idx = xp.sum((man[..., None] >= inner).astype(np.int32), axis=-1)
        return xp.take(slopes, idx) * man + xp.take(intercepts, idx)
    return slopes[0] * man + intercepts[0]


def _reciprocal_mantissa(xp, man, table: SeedTable, n: int, schedule: str):
    """1/man for man in [1, 2): PWL seed + Taylor refinement. No edge cases."""
    y0 = seed_eval(xp, man, table)
    m = exact_residual(man, y0)
    return y0 + y0 * series_sum(xp, m, n, schedule)


def divide_mantissa(xp, man_a, man_b, table: SeedTable, n: int, schedule: str):
    """man_a/man_b for mantissas in [1, 2): series reciprocal + corrected
    final multiply. Returns (q_man, rman) with q_man in (0.5, 2) and rman
    the refined 1/man_b (the divide gradient needs it).

    The naive final multiply ``man_a * rman`` carries rman's full relative
    error into whichever binade the quotient lands in — up to ~2x the
    reciprocal's ULP error, which busts the eq. 17 gate for the paper
    schedule. :func:`fpparts.refine_quotient` folds the exact remainder back
    through rman instead, emulating the unit's full-width final multiplier.
    """
    rman = _reciprocal_mantissa(xp, man_b, table, n, schedule)
    q_man = fpparts.refine_quotient(man_a * rman, man_a, man_b, rman)
    return q_man, rman


def _divide_impl(xp, a, b, table: SeedTable, n: int, schedule: str,
                 underflow: str = "gradual"):
    """Exponent-separated a/b: decompose, mantissa divide, recombine, edges.

    Never materializes 1/b at b's exponent — the refinement stays in the
    [1, 2) mantissa domain and the exponent difference is applied once at
    the end, so the quotient is accurate whenever a/b is representable even
    where recip(b) would under/overflow. Returns (q, rb) with rb ~ 1/b for
    the analytic VJP (rb under/overflowing only zeroes the gradient lane,
    never the primal). The numpy f64 oracle keeps the frexp round-trip
    (numpy's frexp is subnormal-correct and the f32 corpora are normal in
    f64); the jnp f32 path runs the bit-level skeleton, with ``underflow``
    selecting gradual-exact or hardware-FTZ subnormal handling.
    """
    if xp is np:
        s, aa, ab, man_a, man_b, ea, eb = fpparts.decompose_div(xp, a, b)
        q_man, rman = divide_mantissa(xp, man_a, man_b, table, n, schedule)
        rb = fpparts.recombine_recip(xp, rman, eb, b)
        q = fpparts.recombine_div(xp, q_man, ea - eb, s)
        q = fpparts.div_edges(xp, q, a, b, aa, ab, s)
        return q, rb
    return fpparts.bit_divide(
        a, b,
        lambda man_a, man_b: divide_mantissa(xp, man_a, man_b, table, n,
                                             schedule),
        underflow)


def _reciprocal_impl(xp, x, table: SeedTable, n: int, schedule: str,
                     underflow: str = "gradual"):
    """Full FP reciprocal: sign/exponent unpack, mantissa recip, repack, edges.

    numpy keeps the frexp form (f64 oracle); jnp runs the bit-level skeleton
    (see ``_divide_impl`` for the split).
    """
    if xp is not np:
        return fpparts.bit_reciprocal(
            x, lambda man: _reciprocal_mantissa(xp, man, table, n, schedule),
            underflow)
    sign = xp.sign(x)
    ax = xp.abs(x)
    frac, e = xp.frexp(ax)          # ax = frac * 2^e, frac in [0.5, 1)
    man = frac * 2.0                # in [1, 2); exponent is (e - 1)
    rman = _reciprocal_mantissa(xp, man, table, n, schedule)  # in (0.5, 1]
    r = xp.ldexp(rman, 1 - e) * sign
    # Edge semantics match a hardware unit: 0 -> +-inf, inf -> +-0, nan -> nan.
    r = xp.where(ax == 0, xp.copysign(xp.asarray(np.inf, r.dtype), x), r)
    r = xp.where(xp.isinf(ax), xp.copysign(xp.asarray(0.0, r.dtype), x), r)
    r = xp.where(xp.isnan(x), xp.asarray(np.nan, r.dtype), r)
    return r


# ---------------------------------------------------------------- numpy oracle

def reciprocal_np(x, table: SeedTable | None = None, *, n_iters: int | None = None,
                  schedule: str = "paper") -> np.ndarray:
    table = table or compute_segments(5, 53)
    n = table.n_iters if n_iters is None else n_iters
    x = np.asarray(x, np.float64)
    return _reciprocal_impl(np, x, table, n, schedule)


def divide_np(a, b, table: SeedTable | None = None, *, n_iters: int | None = None,
              schedule: str = "paper") -> np.ndarray:
    table = table or compute_segments(5, 53)
    n = table.n_iters if n_iters is None else n_iters
    q, _ = _divide_impl(np, np.asarray(a, np.float64),
                        np.asarray(b, np.float64), table, n, schedule)
    return q


def rsqrt_np(x, table: SeedTable | None = None, *, newton_iters: int = 3) -> np.ndarray:
    table = table or rsqrt_seed_table()
    x = np.asarray(x, np.float64)
    return _rsqrt_impl(np, x, table, newton_iters)


# ------------------------------------------------------------------- jnp path

def attach_grad(r, pairs):
    """Analytic first-order gradient for the bit-level datapath.

    frexp/ldexp/bitcast carry zero cotangent in XLA, so the forward value is
    right but autodiff through the unit silently returns 0. Straight-through
    fix with g_i = dr/dx_i supplied analytically:

        out = r - (stop_grad(corr) - corr),  corr = sum_i g_i*(x_i - sg(x_i))

    corr's *value* is a finite +-0 on every lane (g and x-sg(x) are masked
    finite), so sg(corr) - corr is exactly +0 and subtracting it preserves
    the primal bit-for-bit — signed zeros, infs and nans included — while
    the gradient of the expression is d(corr). Lanes whose analytic g is
    inf/nan (edge results) get zero gradient instead of nan poison.
    """
    import jax
    import jax.numpy as jnp

    rs = jax.lax.stop_gradient(r)
    corr = None
    for x, g in pairs:
        g = jnp.where(jnp.isfinite(g), g, 0.0)
        dx = jnp.where(jnp.isfinite(x), x - jax.lax.stop_gradient(x), 0.0)
        term = jax.lax.stop_gradient(g) * dx
        corr = term if corr is None else corr + term
    return rs - (jax.lax.stop_gradient(corr) - corr)


def reciprocal(x, table: SeedTable | None = None, *, n_iters: int | None = None,
               schedule: str = "factored", underflow: str = "gradual"):
    """Taylor-series reciprocal in JAX. f32 compute; bf16/f16 pass through f32.

    ``underflow="gradual"`` (default) handles subnormal operands and results
    exactly via the bit-level datapath; ``"ftz"`` keeps the fused kernels'
    hardware flush contract.
    """
    table = table or default_table()
    n = table.n_iters if n_iters is None else n_iters
    return fpparts.jnp_reciprocal(
        x, lambda xp, xf: _reciprocal_impl(xp, xf, table, n, schedule,
                                           underflow))


def divide(a, b, table: SeedTable | None = None, *, n_iters: int | None = None,
           schedule: str = "factored", underflow: str = "gradual"):
    """Exponent-separated a/b (never a * recip(b) — see _divide_impl)."""
    table = table or default_table()
    n = table.n_iters if n_iters is None else n_iters
    return fpparts.jnp_divide(
        a, b, lambda xp, af, bf: _divide_impl(xp, af, bf, table, n, schedule,
                                              underflow))


def _newton_rsqrt(u, y, newton_iters: int):
    """Newton refinement of y ~ rsqrt(u), final step residual-compensated.

    Plain Newton steps y <- y*(1.5 - 0.5*u*y^2) leave ~2 ULP of accumulated
    rounding; the last step instead computes the residual r = 1 - u*y^2
    error-free (two Dekker two-products: y^2 = hp + he exactly, then
    u*hp = p2 + e2 exactly, and 1 - p2 is Sterbenz-exact since p2 ~ 1) and
    applies y <- y + y*(r/2) — one rounding on a tiny correction, which
    lands the result within ~0.5 ULP. Pure operator arithmetic: serves the
    f64 numpy oracle and the jnp f32 twin alike.
    """
    for _ in range(max(newton_iters - 1, 0)):
        y = y * (1.5 - 0.5 * u * y * y)
    if newton_iters > 0:
        hp, he = fpparts.two_product(y, y)
        p2, e2 = fpparts.two_product(u, hp)
        r = ((1.0 - p2) - e2) - u * he
        y = y + y * (0.5 * r)
    return y


def _rsqrt_impl(xp, x, table: SeedTable, newton_iters: int,
                underflow: str = "gradual"):
    """1/sqrt(x): even/odd exponent split onto [0.5, 2), PWL seed, Newton.

    numpy keeps the frexp form (f64 oracle); jnp splits the fields at bit
    level so subnormal operands are normalized exactly (rsqrt of every
    positive subnormal is a mid-range normal, so the *result* side never
    underflows — ``underflow`` only selects whether subnormal operands are
    exact ("gradual") or the hardware zero class ("ftz", -> +-inf).
    """
    if xp is not np:
        return _rsqrt_bits(x, table, newton_iters, underflow)
    frac, e = xp.frexp(x)           # x = frac * 2^e, frac in [0.5, 1)
    # s = floor(e/2); u = frac * 2^(e - 2s) in [0.5, 2);  rsqrt(x) = rsqrt(u) * 2^-s
    s = e >> 1
    u = xp.ldexp(frac, e - 2 * s)
    inner = table.inner_boundaries.astype(u.dtype)
    idx = xp.sum((u[..., None] >= inner).astype(np.int32), axis=-1)
    y = xp.take(table.slopes.astype(u.dtype), idx) * u + xp.take(
        table.intercepts.astype(u.dtype), idx)
    y = _newton_rsqrt(u, y, newton_iters)
    r = xp.ldexp(y, -s)
    # IEEE edges (matches jax.lax.rsqrt): +-0 -> +-inf, +inf -> +0,
    # x < 0 (incl. -inf) -> nan, nan -> nan.
    r = xp.where(x == 0, xp.copysign(xp.asarray(np.inf, r.dtype), x), r)
    r = xp.where(xp.isinf(x) & (x > 0), xp.asarray(0.0, r.dtype), r)
    r = xp.where(x < 0, xp.asarray(np.nan, r.dtype), r)
    r = xp.where(xp.isnan(x), xp.asarray(np.nan, r.dtype), r)
    return r


def _rsqrt_bits(x, table: SeedTable, newton_iters: int, underflow: str):
    """jnp f32 rsqrt body on raw bit fields (subnormal-exact decompose).

    Reproduces the frexp form's arithmetic exactly on normal operands (same
    u in [0.5, 2), same Newton steps, same exact power-of-two recombine —
    rsqrt results always land in ~[2^-64, 2^75], so no repack rounding is
    ever needed) while normalizing subnormal operands correctly.
    """
    import jax.numpy as jnp
    from jax import lax

    bits = lax.bitcast_convert_type(x, jnp.uint32)
    mag = bits & fpparts.F32_MAG_MASK
    sign_bits = bits & fpparts.F32_SIGN
    x_zero = mag < fpparts.F32_IMPLICIT if underflow == "ftz" else mag == 0
    x_inf, x_nan = mag == fpparts.F32_EXP_MASK, mag > fpparts.F32_EXP_MASK
    man, e = fpparts.split_f32(mag)                  # |x| = man * 2^e
    man = jnp.where(man == 0, jnp.float32(1.0), man)
    ef = e + 1                                       # frexp convention
    s = ef >> 1                                      # floor(ef / 2)
    odd = ef - 2 * s                                 # 0 or 1
    # u = (man/2) * 2^odd in [0.5, 2): exact scalings only.
    u = jnp.where(odd == 1, man, man * jnp.float32(0.5))
    inner = table.inner_boundaries.astype(np.float32)
    idx = jnp.sum((u[..., None] >= inner).astype(np.int32), axis=-1)
    y = jnp.take(table.slopes.astype(np.float32), idx) * u + jnp.take(
        table.intercepts.astype(np.float32), idx)
    y = _newton_rsqrt(u, y, newton_iters)
    pw = lax.bitcast_convert_type(
        jnp.clip(127 - s, 1, 254).astype(jnp.uint32) << 23, jnp.float32)
    r = y * pw                                       # exact: result is normal
    inf_s = lax.bitcast_convert_type(
        fpparts.F32_EXP_MASK | sign_bits, jnp.float32)
    r = jnp.where(x_zero, inf_s, r)                  # +-0 -> +-inf
    r = jnp.where(x_inf, jnp.float32(0.0), r)        # +inf -> +0
    neg = (sign_bits != 0) & ~x_zero                 # x < 0 (incl. -inf) -> nan
    return jnp.where(neg | x_nan, jnp.float32(np.nan), r)


def rsqrt(x, table: SeedTable | None = None, *, newton_iters: int = 2,
          underflow: str = "gradual"):
    """Taylor/Newton rsqrt in JAX. f32 compute; bf16/f16 pass through f32.

    Gradients come from a ``custom_jvp`` rule (fpparts.jnp_rsqrt — forward
    and reverse mode), not ``attach_grad``: the straight-through arithmetic
    would flush gradual-underflow *primals* on this FTZ/DAZ backend, and a
    custom derivative rule leaves the primal bits untouched.
    """
    table = table or rsqrt_seed_table()
    return fpparts.jnp_rsqrt(
        x, lambda xp, xf: _rsqrt_impl(xp, xf, table, newton_iters, underflow))
