"""Iterative Logarithmic Multiplier (paper §4) and squaring unit (paper §5),
bit-exact on integer mantissas.

ILM (Babic/Avramovic/Bulic, paper eq. 23-27):
    N1*N2 = 2^(k1+k2) + 2^k2*(N1-2^k1) + 2^k1*(N2-2^k2) + (N1-2^k1)(N2-2^k2)
The first three terms are P_approx; the last is the error E, itself a product
of the leading-one-cleared operands -> iterate. Each iteration clears one
leading bit from *each* operand, so ``iters >= min(popcount(a), popcount(b))``
gives the exact product.

Squarer (paper eq. 28):
    N^2 = 4^k + 2^(k+1)*(N-2^k) + (N-2^k)^2
one operand path only (the <50%-hardware claim, see powering.hw_cost).

Two twins again: numpy (uint64; models the paper's full 24/53-bit mantissas)
and jnp (uint32; operand width <= 16 bits so products fit 32 bits — the width
used by the Pallas kernel and the framework's "ilm" emulation mode).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "floor_log2_np", "ilm_mul_np", "ilm_square_np",
    "floor_log2", "ilm_mul", "ilm_square",
    "fp_mul_ilm_np", "fp_recip_ilm_np", "exact_iters_bound",
]


def exact_iters_bound(bits: int) -> int:
    """Iterations guaranteeing exactness for operands of this bit width."""
    return bits


# ---------------------------------------------------------------- numpy twin

def floor_log2_np(x: np.ndarray) -> np.ndarray:
    """floor(log2(x)) for x > 0 (the priority encoder). 0 maps to 0."""
    x = np.asarray(x, np.uint64)
    out = np.zeros_like(x, np.int64)
    v = x.copy()
    for s in (32, 16, 8, 4, 2, 1):
        hit = v >= np.uint64(1 << s)
        out = np.where(hit, out + s, out)
        v = np.where(hit, v >> np.uint64(s), v)
    return out


def ilm_mul_np(a, b, iters: int) -> np.ndarray:
    """ILM product with ``iters`` error-correction iterations (numpy, uint64)."""
    a = np.asarray(a, np.uint64)
    b = np.asarray(b, np.uint64)
    acc = np.zeros(np.broadcast(a, b).shape, np.uint64)
    # uint64 wraparound on np.where-discarded lanes is expected; the kept
    # lanes fit 48 bits (24-bit operands) and are exact.
    with np.errstate(over="ignore"):
        for _ in range(iters):
            valid = (a > 0) & (b > 0)
            k1 = floor_log2_np(np.maximum(a, 1)).astype(np.uint64)
            k2 = floor_log2_np(np.maximum(b, 1)).astype(np.uint64)
            ra = a - (np.uint64(1) << k1)      # LOD residue: N1 - 2^k1
            rb = b - (np.uint64(1) << k2)
            p = (np.uint64(1) << (k1 + k2)) + (ra << k2) + (rb << k1)
            acc = np.where(valid, acc + p, acc)
            a = np.where(valid, ra, a)
            b = np.where(valid, rb, b)
    return acc


def ilm_square_np(a, iters: int) -> np.ndarray:
    """Squaring unit: iterates N^2 = 4^k + 2^(k+1)(N-2^k) + (N-2^k)^2."""
    a = np.asarray(a, np.uint64)
    acc = np.zeros_like(a)
    for _ in range(iters):
        valid = a > 0
        k = floor_log2_np(np.maximum(a, 1)).astype(np.uint64)
        r = a - (np.uint64(1) << k)
        p = (np.uint64(1) << (np.uint64(2) * k)) + (r << (k + np.uint64(1)))
        acc = np.where(valid, acc + p, acc)
        a = np.where(valid, r, a)
    return acc


# ------------------------------------------------------------------ jnp twin

def floor_log2(x):
    """floor(log2(x)) on uint32 lanes via bit-smear + population count."""
    import jax.numpy as jnp
    from jax import lax

    v = x.astype(jnp.uint32)
    for s in (1, 2, 4, 8, 16):
        v = v | (v >> s)
    return lax.population_count(v).astype(jnp.int32) - 1


def ilm_mul(a, b, iters: int):
    """ILM product (jnp, uint32). Operands must be < 2^16 for exact headroom."""
    import jax.numpy as jnp

    a = a.astype(jnp.uint32)
    b = b.astype(jnp.uint32)
    acc = jnp.zeros(jnp.broadcast_shapes(a.shape, b.shape), jnp.uint32)
    one = jnp.uint32(1)
    for _ in range(iters):
        valid = (a > 0) & (b > 0)
        k1 = jnp.maximum(floor_log2(jnp.maximum(a, 1)), 0).astype(jnp.uint32)
        k2 = jnp.maximum(floor_log2(jnp.maximum(b, 1)), 0).astype(jnp.uint32)
        ra = a - (one << k1)
        rb = b - (one << k2)
        p = (one << (k1 + k2)) + (ra << k2) + (rb << k1)
        acc = jnp.where(valid, acc + p, acc)
        a = jnp.where(valid, ra, a)
        b = jnp.where(valid, rb, b)
    return acc


def ilm_square(a, iters: int):
    """Squaring unit (jnp, uint32). Operand < 2^16."""
    import jax.numpy as jnp

    a = a.astype(jnp.uint32)
    acc = jnp.zeros_like(a)
    one = jnp.uint32(1)
    for _ in range(iters):
        valid = a > 0
        k = jnp.maximum(floor_log2(jnp.maximum(a, 1)), 0).astype(jnp.uint32)
        r = a - (one << k)
        p = (one << (k + k)) + (r << (k + one))
        acc = jnp.where(valid, acc + p, acc)
        a = jnp.where(valid, r, a)
    return acc


# ------------------------------------- floating-point emulation (numpy oracle)

def fp_mul_ilm_np(x, y, *, iters: int, mant_bits: int = 24) -> np.ndarray:
    """FP multiply through the ILM on quantized mantissas (hardware emulation)."""
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    fx, ex = np.frexp(np.abs(x))
    fy, ey = np.frexp(np.abs(y))
    scale = 1 << (mant_bits - 1)
    mx = np.round(fx * 2 * scale).astype(np.uint64)   # in [2^(mb-1), 2^mb]
    my = np.round(fy * 2 * scale).astype(np.uint64)
    p = ilm_mul_np(mx, my, iters).astype(np.float64)
    r = np.ldexp(p / (4.0 * scale * scale), (ex - 1) + (ey - 1) + 2)
    return r * np.sign(x) * np.sign(y)


def fp_recip_ilm_np(x, *, table=None, iters_mul: int = 24, n_terms: int = 5) -> np.ndarray:
    """Full §7 system emulation: PWL seed + Taylor series, all multiplies via ILM.

    This is the bit-faithful model of the paper's Fig. 7 datapath: the powering
    unit evaluates the series with the ILM multiplier/squarer; the final
    a*b^-1 multiply also goes through the ILM.
    """
    from .seeds import compute_segments
    from . import powering

    table = table or compute_segments(5, 53)
    x = np.asarray(x, np.float64)
    frac, e = np.frexp(np.abs(x))
    man = frac * 2.0
    y0 = table.seed(man)
    mul = lambda a, b: fp_mul_ilm_np(a, b, iters=iters_mul)
    m = 1.0 - mul(man, y0)
    powers = powering.eval_powers(
        m, n_terms, mul=mul,
        square=lambda a: fp_mul_ilm_np(a, a, iters=iters_mul))
    acc = np.ones_like(m) + (m if n_terms >= 1 else 0.0)
    for k in range(2, n_terms + 1):
        acc = acc + powers[k]
    rman = mul(y0, acc)
    return np.ldexp(rman, 1 - e) * np.sign(x)
