"""Fault-tolerant training loop: resume, preemption, stragglers, checkpoints.

The loop is deliberately restart-idempotent:
  * data batch(step) is a pure function of the step -> resume replays nothing;
  * checkpoints carry (params, opt, step) and are atomic;
  * on entry the loop restores the newest complete checkpoint if present.
tests/test_train_loop.py kills the loop mid-run and asserts the resumed run's
final params are bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim import adamw
from . import checkpoint as ckpt_lib
from . import fault
from .step import TrainState, init_state, train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: Optional[str] = None
    ckpt_keep: int = 3
    n_micro: int = 1
    log_every: int = 10
    seed: int = 0


def run(cfg: ModelConfig, loop: LoopConfig, data_cfg: DataConfig,
        opt_cfg: Optional[adamw.AdamWConfig] = None,
        injector: Optional[fault.FailureInjector] = None,
        log: Callable[[str], None] = print) -> Dict[str, Any]:
    """Train; returns {'state': final TrainState, 'losses': [...], ...}."""
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        state_dtype=cfg.opt_state_dtype, division=cfg.division)
    data = SyntheticLM(data_cfg)

    key = jax.random.PRNGKey(loop.seed)
    params = init_params(cfg, key)
    state = init_state(cfg, params, opt_cfg)

    start_step = 0
    if loop.ckpt_dir:
        restored_step, restored = ckpt_lib.restore_latest(loop.ckpt_dir, state)
        if restored_step is not None:
            state = restored
            start_step = restored_step
            log(f"[resume] restored checkpoint at step {restored_step}")

    step_fn = jax.jit(
        lambda s, b: train_step(cfg, opt_cfg, s, b, n_micro=loop.n_micro),
        donate_argnums=(0,))

    watchdog = fault.StragglerWatchdog()
    losses = []
    with fault.PreemptionGuard() as guard:
        for step in range(start_step, loop.total_steps):
            t0 = time.perf_counter()
            batch = jax.tree_util.tree_map(jnp.asarray, data.batch(step))
            if injector is not None:
                injector.check(step)
            state, metrics = step_fn(state, batch)
            dt = time.perf_counter() - t0
            ev = watchdog.observe(step, dt)
            if ev is not None:
                log(f"[straggler] step {ev.step}: {ev.duration:.3f}s "
                    f"(ewma {ev.ewma:.3f}s)")
            loss = float(metrics["loss"])
            losses.append(loss)
            if step % loop.log_every == 0:
                log(f"step {step:5d} loss {loss:.4f} ({dt*1e3:.0f} ms)")
            should_ckpt = loop.ckpt_dir and (
                (step + 1) % loop.ckpt_every == 0 or guard.preempted
                or step + 1 == loop.total_steps)
            if should_ckpt:
                ckpt_lib.save(loop.ckpt_dir, step + 1, state, keep=loop.ckpt_keep)
            if guard.preempted:
                log(f"[preempt] checkpointed at step {step + 1}; exiting")
                break
    return {"state": state, "losses": losses,
            "straggler_events": watchdog.events, "last_step": step + 1}
