"""Fault handling: preemption hooks, straggler watchdog, failure injection.

* ``PreemptionGuard`` — installs SIGTERM/SIGINT handlers that flip a flag the
  training loop polls; on preemption the loop writes a final checkpoint and
  exits 0 (the scheduler restarts the job, which auto-resumes).
* ``StragglerWatchdog`` — per-step wall-time EWMA; a step slower than
  ``threshold`` x the EWMA is logged as a straggler event. On a real fleet the
  callback feeds the scheduler's slow-host eviction; here it records events
  (tests inject a synthetic slow step and assert detection).
* ``FailureInjector`` — deterministic kill at step N (tests use it to prove
  kill -> restart -> resume produces bit-identical training to an uninterrupted
  run, see tests/test_train_loop.py).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Callable, List, Optional


class PreemptionGuard:
    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = False
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag = True

    @property
    def preempted(self) -> bool:
        return self._flag


@dataclass
class StragglerEvent:
    step: int
    duration: float
    ewma: float


class StragglerWatchdog:
    """Flags steps slower than threshold x EWMA (warmup steps excluded)."""

    def __init__(self, threshold: float = 3.0, alpha: float = 0.2, warmup: int = 3):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: Optional[float] = None
        self.events: List[StragglerEvent] = []
        self._n = 0

    def observe(self, step: int, duration: float) -> Optional[StragglerEvent]:
        self._n += 1
        if self._n <= self.warmup:
            self.ewma = duration if self.ewma is None else (
                self.alpha * duration + (1 - self.alpha) * self.ewma)
            return None
        ev = None
        if self.ewma is not None and duration > self.threshold * self.ewma:
            ev = StragglerEvent(step, duration, self.ewma)
            self.events.append(ev)
        else:
            # stragglers don't poison the EWMA
            self.ewma = self.alpha * duration + (1 - self.alpha) * self.ewma
        return ev


class FailureInjector:
    """Raises at a chosen step — simulates a node loss for resume tests."""

    class Injected(RuntimeError):
        pass

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step

    def check(self, step: int):
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise FailureInjector.Injected(f"injected failure at step {step}")
