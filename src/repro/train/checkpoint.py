"""Fault-tolerant checkpointing: atomic, sharded-aware, mesh-shape-agnostic.

Design for 1000+ nodes:
  * each host writes ONLY its addressable shards (np arrays) — no gather, no
    host-0 bottleneck; single-host here degenerates to full arrays;
  * writes go to a temp dir, fsync'd, then os.replace -> atomic: a checkpoint
    either exists completely or not at all (kill -9 mid-write is safe);
  * checkpoints store *logical* (unsharded) array values + the pytree spec, so
    a restart may use a different mesh shape (elastic resume) — shardings are
    reapplied at load via jax.device_put;
  * keep-last-k garbage collection; ``latest_step`` scans for the newest
    complete checkpoint (marker file written last).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional, Tuple

import jax
import ml_dtypes  # noqa: F401  (registers bfloat16 et al. with numpy)
import numpy as np

_MARKER = "COMPLETE"


def _np_dtype(name: str) -> np.dtype:
    return np.dtype(getattr(ml_dtypes, name, name))


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, keep: int = 3) -> str:
    """Write checkpoint for ``step`` under ``path``. Returns the final dir."""
    final = os.path.join(path, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    arrays = {}
    dtypes, shapes = [], []
    for i, leaf in enumerate(leaves):
        arr = np.ascontiguousarray(np.asarray(jax.device_get(leaf)))
        dtypes.append(str(arr.dtype))
        shapes.append(list(arr.shape))
        # raw-bytes storage: npz has no codecs for ml_dtypes (bf16 etc.)
        arrays[f"leaf_{i}"] = arr.view(np.uint8).reshape(-1)
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    meta = {
        "step": int(step),
        "n_leaves": len(leaves),
        "treedef": str(treedef),
        "dtypes": dtypes,
        "shapes": shapes,
    }
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump(meta, f)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, _MARKER), "w") as f:
        f.write("ok")
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _gc(path, keep)
    return final


def _gc(path: str, keep: int):
    steps = sorted(all_steps(path))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(path, f"step_{s:010d}"), ignore_errors=True)


def all_steps(path: str):
    if not os.path.isdir(path):
        return []
    out = []
    for name in os.listdir(path):
        if name.startswith("step_") and not name.endswith(".tmp"):
            full = os.path.join(path, name)
            if os.path.exists(os.path.join(full, _MARKER)):
                out.append(int(name[5:]))
    return out


def latest_step(path: str) -> Optional[int]:
    steps = all_steps(path)
    return max(steps) if steps else None


def restore(path: str, step: int, like: Any, shardings: Any = None) -> Any:
    """Load checkpoint ``step`` into the structure of ``like``.

    ``shardings`` (optional pytree of NamedSharding) reshards on load —
    this is the elastic-resume path: the saved arrays are logical values,
    placement is decided by the *current* mesh.
    """
    final = os.path.join(path, f"step_{step:010d}")
    if not os.path.exists(os.path.join(final, _MARKER)):
        raise FileNotFoundError(f"incomplete or missing checkpoint: {final}")
    data = np.load(os.path.join(final, "arrays.npz"))
    with open(os.path.join(final, "meta.json")) as f:
        meta = json.load(f)
    leaves, treedef = _flatten(like)
    assert len(leaves) == len(data.files), "checkpoint/leaf count mismatch"
    new_leaves = []
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(leaves))
    for i, (ref, shd) in enumerate(zip(leaves, shard_leaves)):
        raw = data[f"leaf_{i}"]
        arr = raw.view(_np_dtype(meta["dtypes"][i])).reshape(meta["shapes"][i])
        if shd is not None:
            new_leaves.append(jax.device_put(arr, shd))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, new_leaves)


def restore_latest(path: str, like: Any, shardings: Any = None
                   ) -> Tuple[Optional[int], Any]:
    step = latest_step(path)
    if step is None:
        return None, like
    return step, restore(path, step, like, shardings)
