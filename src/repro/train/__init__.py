from . import checkpoint, fault, loop, step
from .step import TrainState, init_state, loss_fn, train_step

__all__ = ["checkpoint", "fault", "loop", "step",
           "TrainState", "init_state", "loss_fn", "train_step"]
