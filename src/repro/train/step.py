"""Loss and train-step: grad-accumulation microbatching, AdamW, compression.

The train step is one jit-compiled function over (state, batch):
  * batch (B_local_total, S) splits into ``n_micro`` microbatches;
  * a lax.scan accumulates grads (f32) across microbatches — activations for
    only one microbatch live at a time (remat inside the model bounds them
    further to one layer-period);
  * gradients average over the data axes implicitly via SPMD partial-sums of
    the batch-sharded loss; the optional cross-pod int8 compression hook
    applies where the mesh has a 'pod' axis (dryrun variant flag).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward
from repro.optim import adamw


class TrainState(NamedTuple):
    params: Any
    opt: adamw.AdamWState
    step: jax.Array


def init_state(cfg: ModelConfig, params, opt_cfg: adamw.AdamWConfig) -> TrainState:
    return TrainState(params=params, opt=adamw.init(params, opt_cfg),
                      step=jnp.zeros((), jnp.int32))


def abstract_state(cfg: ModelConfig, params_abstract,
                   opt_cfg: adamw.AdamWConfig) -> TrainState:
    return TrainState(params=params_abstract,
                      opt=adamw.abstract_state(params_abstract, opt_cfg),
                      step=jax.ShapeDtypeStruct((), jnp.int32))


def cross_entropy(logits, labels):
    """Mean CE. logits f32 (B,S,V) possibly vocab-sharded; labels (B,S)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def loss_fn(cfg: ModelConfig, params, batch: Dict[str, jax.Array]):
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = batch["enc_embeds"]
    if cfg.embed_inputs and not cfg.is_encoder_decoder:
        logits, _, aux = forward(cfg, params, embeds=batch["embeds"],
                                 mode="train", **kw)
    else:
        logits, _, aux = forward(cfg, params, tokens=batch["tokens"],
                                 mode="train", **kw)
    ce = cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


def _split_micro(batch, n_micro: int):
    """(B, ...) -> (n_micro, B/n_micro, ...) per leaf."""
    def sp(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape(n_micro, b // n_micro, *x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def grads_fn(cfg: ModelConfig, params, batch, n_micro: int):
    """Microbatched value-and-grad via lax.scan accumulation (f32 grads)."""
    gfun = jax.value_and_grad(lambda p, b: loss_fn(cfg, p, b), has_aux=True)
    if n_micro <= 1:
        (loss, metrics), grads = gfun(params, batch)
        return loss, metrics, jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32), grads)

    micro = _split_micro(batch, n_micro)
    g0 = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def body(acc, mb):
        g_acc, loss_acc = acc
        (loss, _), g = gfun(params, mb)
        g_acc = jax.tree_util.tree_map(
            lambda a, b: a + b.astype(jnp.float32), g_acc, g)
        return (g_acc, loss_acc + loss), None

    (g_sum, loss_sum), _ = jax.lax.scan(body, (g0, jnp.float32(0.0)), micro)
    inv = 1.0 / n_micro
    grads = jax.tree_util.tree_map(lambda g: g * inv, g_sum)
    loss = loss_sum * inv
    return loss, {"ce": loss, "aux": jnp.float32(0.0)}, grads


def train_step(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, state: TrainState,
               batch, *, n_micro: int = 1, lr_scale=1.0,
               compress_axis: Optional[str] = None, err_tree=None):
    """One optimizer step. Returns (new_state, metrics[, new_err_tree])."""
    loss, metrics, grads = grads_fn(cfg, state.params, batch, n_micro)
    new_err = None
    if compress_axis is not None:
        from repro.optim import compress
        grads, new_err = compress.psum_compressed(grads, err_tree, compress_axis)
    new_params, new_opt = adamw.update(grads, state.opt, state.params, opt_cfg,
                                       lr_scale)
    new_state = TrainState(params=new_params, opt=new_opt, step=state.step + 1)
    metrics = dict(metrics, loss=loss, step=state.step)
    if compress_axis is not None:
        return new_state, metrics, new_err
    return new_state, metrics
