"""Conformance runner: delivered ULP accuracy over (mode x schedule x n_iters x dtype).

Sweeps every cell of the division-mode grid against the f64 oracle on the
stratified operand corpus (eval/ulp.py) and emits a machine-readable report:

    PYTHONPATH=src python -m repro.eval.conformance            # full grid
    PYTHONPATH=src python -m repro.eval.conformance --quick    # CI-sized
    PYTHONPATH=src python -m repro.eval.conformance --json out.json

The five algorithm families on identical footing: exact (XLA), Taylor with
the paper's §6 schedule, Taylor factored, Goldschmidt (core/goldschmidt.py,
plus its fused-kernel twin), and the 16-bit ILM emulation; op in
{recip, div, rsqrt} plus the consumer tier {softmax, rmsnorm} (row
corpora and unit-isolating gates in eval/consumers.py). Masking is
underflow-policy-aware: gradual cells (the
bit-level jnp twins) measure subnormal operands and results, FTZ cells
exclude them as the flush edge class. The process exits non-zero if any
cell fails its gate (edge contract, or > 2 max ULP at the n >= 2 non-ILM
operating points), so CI can consume the run directly. Consumed by
tests/test_conformance.py (the paper's eq. 17 precision claim as a hard
gate) and benchmarks/run.py (bench_ulp_accuracy).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys
import time
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.division_modes import (DivisionConfig, div, recip, rsqrt,
                                       softmax, rmsnorm, effective_underflow)
from repro.core.seeds import compute_segments
from . import consumers, ulp

__all__ = ["Cell", "default_grid", "run_cell", "run_conformance",
           "format_table", "cell_gate", "main"]

# (n_iters, precision_bits) operating points: the paper's accuracy dial.
DIAL = ((1, 12), (2, 24), (3, 30))

# The eq. 17 operating point: every non-ILM cell at n >= 2 must deliver
# <= 2 max ULP (the paper's gate); n=1 @ 12-bit is the loose end of the
# dial by design and is not ULP-gated. ILM is ~12-bit by construction.
GATE_MAX_ULP = 2.0


@dataclasses.dataclass(frozen=True)
class Cell:
    """One conformance grid cell. schedule '-' = not applicable to the mode."""

    mode: str
    schedule: str = "-"
    n_iters: int = 2
    precision_bits: int = 24
    dtype: str = "float32"
    op: str = "recip"

    @property
    def key(self) -> str:
        return f"{self.op}/{self.mode}/{self.schedule}/n{self.n_iters}" \
               f"p{self.precision_bits}/{self.dtype}"

    def config(self) -> DivisionConfig:
        sched = self.schedule if self.schedule != "-" else "factored"
        return DivisionConfig(mode=self.mode, n_iters=self.n_iters,
                              precision_bits=self.precision_bits,
                              schedule=sched)


def default_grid(dtypes: Sequence[str] = ulp.DTYPES,
                 dial: Sequence = DIAL, quick: bool = False) -> List[Cell]:
    """Every (op x mode x schedule x n_iters x dtype) cell of the grid.

    op=rsqrt runs at the f32 operating point only (rsqrt's accuracy dial is
    ``rsqrt_newton``, not the series depth; taylor and goldschmidt share the
    jnp rsqrt datapath by design, and both Pallas modes share the fused
    full-edge rsqrt kernel — it has no schedule knob — so the
    goldschmidt_pallas rsqrt column is collapsed into the taylor_pallas
    cell rather than re-measuring an identical datapath). The consumer ops
    (softmax, rmsnorm) run at the (2, 24) operating point across every
    mode: their dial is gated by the vs-exact-twin and row-sum metrics, not
    the oracle ULP (see eval/consumers.py).
    """
    if quick:
        dial = [d for d in dial if d == (2, 24)] or [dial[0]]
    cells: List[Cell] = []
    for dt in dtypes:
        for op in ("recip", "div"):
            cells.append(Cell("exact", dtype=dt, op=op))
            for n, p in dial:
                for sched in ("paper", "factored"):
                    cells.append(Cell("taylor", sched, n, p, dt, op=op))
                cells.append(Cell("taylor_pallas", "factored", n, p, dt, op=op))
                cells.append(Cell("goldschmidt", "-", n, p, dt, op=op))
                cells.append(Cell("goldschmidt_pallas", "-", n, p, dt, op=op))
            # ILM carries ~12 mantissa bits by construction — one cell each.
            cells.append(Cell("ilm", "-", 2, 24, dt, op=op))
        cells.append(Cell("exact", dtype=dt, op="rsqrt"))
        for sched in ("paper", "factored"):
            cells.append(Cell("taylor", sched, 2, 24, dt, op="rsqrt"))
        cells.append(Cell("taylor_pallas", "factored", 2, 24, dt, op="rsqrt"))
        cells.append(Cell("goldschmidt", "-", 2, 24, dt, op="rsqrt"))
        cells.append(Cell("ilm", "-", 2, 24, dt, op="rsqrt"))
        for op in consumers.CONSUMER_OPS:
            cells.append(Cell("exact", dtype=dt, op=op))
            for sched in ("paper", "factored"):
                cells.append(Cell("taylor", sched, 2, 24, dt, op=op))
            cells.append(Cell("taylor_pallas", "factored", 2, 24, dt, op=op))
            cells.append(Cell("goldschmidt", "-", 2, 24, dt, op=op))
            cells.append(Cell("goldschmidt_pallas", "-", 2, 24, dt, op=op))
            cells.append(Cell("ilm", "-", 2, 24, dt, op=op))
    return cells


def _edge_failures(x64: np.ndarray, r64: np.ndarray) -> int:
    """IEEE contract on the edge corpus: +-0 -> +-inf, +-inf -> +-0, nan -> nan."""
    fails = 0
    zero = x64 == 0
    fails += int(np.sum(zero & ~(np.isinf(r64)
                                 & (np.signbit(r64) == np.signbit(x64)))))
    inf = np.isinf(x64)
    fails += int(np.sum(inf & ~((r64 == 0)
                                & (np.signbit(r64) == np.signbit(x64)))))
    nan = np.isnan(x64)
    fails += int(np.sum(nan & ~np.isnan(r64)))
    return fails


def _div_edge_failures(a64: np.ndarray, b64: np.ndarray,
                       q64: np.ndarray) -> int:
    """IEEE special-value contract for a/b on the operand-edge corpus.

    Checks only the lanes whose outcome is fixed by the operands' special
    values (zeros, infs, nans — including sign rules); finite/finite lanes
    that merely overflow or underflow are the FTZ class, judged elsewhere.
    """
    sign = np.signbit(a64) ^ np.signbit(b64)
    a_zero, b_zero = a64 == 0, b64 == 0
    a_inf, b_inf = np.isinf(a64), np.isinf(b64)
    a_nan, b_nan = np.isnan(a64), np.isnan(b64)
    finite_a = np.isfinite(a64)
    finite_b = np.isfinite(b64)
    # Subnormal operands are the FTZ class (kernels legitimately flush them
    # to zero before the special-value logic) — excluded from the sign-rule
    # lanes below; nan propagation holds regardless. f32 and bf16 share
    # emin = -126.
    tiny = np.ldexp(1.0, -126)
    subn = (((a64 != 0) & finite_a & (np.abs(a64) < tiny))
            | ((b64 != 0) & finite_b & (np.abs(b64) < tiny)))
    a_zero, b_zero = a_zero & ~subn, b_zero & ~subn
    a_inf, b_inf = a_inf & ~subn, b_inf & ~subn
    fails = 0
    # x/0 (x finite nonzero or inf) -> signed inf.
    lane = b_zero & ~a_zero & ~a_nan
    fails += int(np.sum(lane & ~(np.isinf(q64) & (np.signbit(q64) == sign))))
    # 0/y (y nonzero finite or inf) -> signed zero.
    lane = a_zero & ~b_zero & ~b_nan
    fails += int(np.sum(lane & ~((q64 == 0) & (np.signbit(q64) == sign))))
    # inf/y (y finite) -> signed inf;  x/inf (x finite) -> signed zero.
    lane = a_inf & finite_b & ~b_nan
    fails += int(np.sum(lane & ~(np.isinf(q64) & (np.signbit(q64) == sign))))
    lane = b_inf & finite_a & ~a_nan
    fails += int(np.sum(lane & ~((q64 == 0) & (np.signbit(q64) == sign))))
    # Invalid: 0/0, inf/inf, any nan operand -> nan.
    lane = (a_zero & b_zero) | (a_inf & b_inf) | a_nan | b_nan
    fails += int(np.sum(lane & ~np.isnan(q64)))
    return fails


def _rsqrt_edge_failures(x64: np.ndarray, r64: np.ndarray) -> int:
    """IEEE contract for rsqrt on the edge corpus.

    ±0 -> ±inf, +inf -> +0, x < 0 (incl. -inf) -> nan, nan -> nan.
    Subnormal-magnitude operands are policy-dependent (gradual: exact;
    FTZ: the zero class -> ±inf) and are judged by the ULP strata /
    policy tests instead.
    """
    subn = np.isfinite(x64) & (x64 != 0) & (np.abs(x64) < np.ldexp(1.0, -126))
    fails = 0
    zero = (x64 == 0) & ~subn
    fails += int(np.sum(zero & ~(np.isinf(r64)
                                 & (np.signbit(r64) == np.signbit(x64)))))
    fails += int(np.sum(np.isposinf(x64)
                        & ~((r64 == 0) & ~np.signbit(r64))))
    neg = (x64 < 0) & ~subn
    fails += int(np.sum(neg & ~np.isnan(r64)))
    fails += int(np.sum(np.isnan(x64) & ~np.isnan(r64)))
    return fails


def _softmax_edge_failures(cfg: DivisionConfig, dtype: str) -> int:
    """Masked-softmax contract on the edge rows (eval/consumers.py):

    fully-masked row -> exact zeros (never 0 * recip(0) = nan), single-
    survivor row -> probability 1 within 2 ULP-equivalents (ILM: its
    ~12-bit dial) with exact zeros elsewhere, nan row -> nan everywhere.
    """
    import jax.numpy as jnp

    p, _, _ = ulp._fmt(dtype)
    rows = consumers.softmax_edge_rows(dtype)
    out = np.asarray(softmax(jnp.asarray(rows), -1, cfg)).astype(np.float64)
    tol = 2.0 ** -10 if cfg.mode == "ilm" else 2.0 * 2.0 ** (1 - p)
    fails = int(np.sum(out[0] != 0.0))
    fails += int(not abs(out[1, 0] - 1.0) <= tol)
    fails += int(np.sum(out[1, 1:] != 0.0))
    fails += int(np.sum(~np.isnan(out[2])))
    return fails


def _rmsnorm_edge_failures(cfg: DivisionConfig, dtype: str) -> int:
    """RMSNorm edge contract: an all-zero row normalizes to exact zeros
    (0 * rsqrt(eps) * w) and a nan row propagates nan, in every mode."""
    import jax.numpy as jnp

    dt = ulp._resolve_dtype(dtype)
    d = 16
    rows = np.zeros((2, d)).astype(dt)
    rows[1, :] = 1.0
    rows[1, d // 2] = np.nan
    w = jnp.asarray(consumers.rmsnorm_weight(d))
    out = np.asarray(rmsnorm(jnp.asarray(rows), w, cfg)).astype(np.float64)
    fails = int(np.sum(out[0] != 0.0))
    fails += int(np.sum(~np.isnan(out[1])))
    return fails


def run_cell(cell: Cell, n_log: int = 4096, n_man: int = 4096,
             seed: int = 0) -> Dict:
    """Measure one cell over the stratified sweep; returns a report dict.

    Masks are policy-aware: cells whose delivered underflow policy is
    "gradual" (the bit-level jnp twins) keep subnormal operands and
    gradual-underflow results *inside* the ULP statistics — exactness there
    is the point of the datapath — while FTZ cells (fused kernels, ILM,
    XLA-native exact on this backend) exclude them as the flush edge class.
    """
    import jax.numpy as jnp

    cfg = cell.config()
    gradual = effective_underflow(cfg) == "gradual"
    table = compute_segments(cell.n_iters, cell.precision_bits)
    t0 = time.perf_counter()
    per_stratum: Dict[str, Dict] = {}
    edge_fail = 0
    agg: List[np.ndarray] = []
    extra: Dict = {}       # op-specific gated metrics (consumer cells)

    def measure(name: str, r_np: np.ndarray, exact: np.ndarray,
                mask: np.ndarray) -> None:
        """Shared per-stratum bookkeeping for all ops."""
        errs = ulp.ulp_error(r_np, exact, cell.dtype, where=mask)
        per_stratum[name] = ulp.summarize(errs, mask)
        agg.append(errs[mask])

    def operand_mask(x64: np.ndarray) -> np.ndarray:
        m = ulp.oracle_mask(x64, cell.dtype)
        if gradual:
            m = m | ulp.subnormal_mask(x64, cell.dtype)
        return m

    def result_mask(exact: np.ndarray, cliffs: bool) -> np.ndarray:
        m = ulp.oracle_mask(exact, cell.dtype)
        if cliffs:
            m = m & (ulp.cliff_guard(exact, cell.dtype) if not gradual
                     else ulp.overflow_guard(exact, cell.dtype))
        if gradual:
            # Gradual cells measure subnormal exact results too (the RNE
            # integer repack rounds into the subnormal lattice).
            m = m | ulp.subnormal_mask(exact, cell.dtype)
        return m

    if cell.op == "div":
        pairs = ulp.div_sweep(cell.dtype, n_log=n_log, n_man=n_man,
                              boundaries=table.boundaries, seed=seed)
        for name, (a_s, b_s) in pairs.items():
            a64 = np.asarray(a_s).astype(np.float64)
            b64 = np.asarray(b_s).astype(np.float64)
            q = div(jnp.asarray(a_s), jnp.asarray(b_s), cfg)
            q_np = np.asarray(q)
            with np.errstate(divide="ignore", invalid="ignore"):
                exact = a64 / b64
            # FTZ cells: ULP stats where the exact quotient AND both
            # operands are normal, quotients within 2 ULP of the cliffs
            # guard-banded. Gradual cells: subnormal operands/results are
            # measured; only the overflow cliff keeps its guard band.
            mask = (result_mask(exact, cliffs=True)
                    & operand_mask(a64) & operand_mask(b64))
            measure(name, q_np, exact, mask)
            if name == "subnormals":
                # FTZ signature on subnormal denominators: flushed-b lanes
                # divide as x/0 -> inf (or 0 for flushed numerators).
                q64 = q_np.astype(np.float64)
                per_stratum[name]["ftz_frac"] = float(
                    np.mean(np.isinf(q64) | (q64 == 0)))
            if name == "edges":
                edge_fail = _div_edge_failures(a64, b64,
                                               q_np.astype(np.float64))
    elif cell.op == "rsqrt":
        strata = ulp.rsqrt_sweep(cell.dtype, n_log=n_log, n_man=n_man,
                                 seed=seed)
        for name, xs in strata.items():
            x64 = np.asarray(xs).astype(np.float64)
            r_np = np.asarray(rsqrt(jnp.asarray(xs), cfg))
            with np.errstate(divide="ignore", invalid="ignore"):
                exact = 1.0 / np.sqrt(x64)     # x<0 -> nan, 0 -> inf
            # rsqrt never under/overflows on normal or subnormal operands,
            # so no cliff guards apply.
            mask = result_mask(exact, cliffs=False) & operand_mask(x64)
            measure(name, r_np, exact, mask)
            if name == "subnormals":
                r64 = r_np.astype(np.float64)
                per_stratum[name]["ftz_frac"] = float(
                    np.mean(np.isinf(r64) | (r64 == 0)))
            if name == "edges":
                edge_fail = _rsqrt_edge_failures(x64,
                                                 r_np.astype(np.float64))
    elif cell.op in consumers.CONSUMER_OPS:
        # Consumer cells: oracle ULP stats are informational (the shared
        # exp/reduction error dominates on hard strata, in every mode);
        # the gated numbers are the vs-exact-twin integer ULP and, for
        # softmax, the row-sum accuracy. See eval/consumers.py.
        exact_cfg = DivisionConfig(mode="exact")
        rows = max(8, min(n_log, 4096) // 64)
        d = 128
        row_sum_max = 0.0
        vs_exact_max = 0
        if cell.op == "softmax":
            strata_rows = consumers.softmax_rows(cell.dtype, rows, d, seed)
        else:
            strata_rows = consumers.rmsnorm_rows(cell.dtype, rows, d, seed)
            w = consumers.rmsnorm_weight(d, seed)
            wj = jnp.asarray(w)
        for name, xs in strata_rows.items():
            xj = jnp.asarray(xs)
            x64 = np.asarray(xs).astype(np.float64)
            if cell.op == "softmax":
                out = np.asarray(softmax(xj, -1, cfg))
                twin = np.asarray(softmax(xj, -1, exact_cfg))
                exact = consumers.softmax_oracle(x64)
                mask = ulp.oracle_mask(exact, cell.dtype)
            else:
                out = np.asarray(rmsnorm(xj, wj, cfg))
                twin = np.asarray(rmsnorm(xj, wj, exact_cfg))
                exact = consumers.rmsnorm_oracle(x64, w.astype(np.float64))
                mask = (ulp.oracle_mask(exact, cell.dtype)
                        & ~ulp.subnormal_mask(x64, cell.dtype))
            measure(name, out, exact, mask)
            ve = consumers.vs_exact_int_ulp(out, twin, exact, cell.dtype)
            per_stratum[name]["vs_exact_max_ulp"] = ve
            vs_exact_max = max(vs_exact_max, ve)
            if cell.op == "softmax":
                rs = float(consumers.row_sum_ulp1(out, cell.dtype).max())
                per_stratum[name]["row_sum_max_ulp1"] = rs
                row_sum_max = max(row_sum_max, rs)
        if cell.op == "softmax":
            edge_fail = _softmax_edge_failures(cfg, cell.dtype)
        else:
            edge_fail = _rmsnorm_edge_failures(cfg, cell.dtype)
        extra = {"vs_exact_max_ulp": vs_exact_max}
        if cell.op == "softmax":
            extra["row_sum_max_ulp1"] = row_sum_max
    else:
        strata = ulp.stratified_sweep(cell.dtype, n_log=n_log, n_man=n_man,
                                      boundaries=table.boundaries, seed=seed)
        for name, xs in strata.items():
            x64 = np.asarray(xs).astype(np.float64)
            r = recip(jnp.asarray(xs), cfg)
            r_np = np.asarray(r)
            with np.errstate(divide="ignore", invalid="ignore"):
                exact = 1.0 / x64          # IEEE: +-0 -> +-inf, +-inf -> +-0
            mask = result_mask(exact, cliffs=gradual) & operand_mask(x64)
            measure(name, r_np, exact, mask)
            if name == "subnormals":
                per_stratum[name]["ftz_frac"] = float(
                    np.mean(np.isinf(r_np.astype(np.float64))))
            if name == "edges":
                edge_fail = _edge_failures(x64, r_np.astype(np.float64))
    allv = np.concatenate(agg) if agg else np.zeros(0)
    out = dataclasses.asdict(cell)
    out.update({
        "key": cell.key,
        "underflow": effective_underflow(cfg),
        "overall": ulp.summarize(allv),
        "strata": per_stratum,
        "edge_failures": edge_fail,
        "seconds": round(time.perf_counter() - t0, 3),
    })
    out.update(extra)
    out["pass"] = cell_gate(out)
    return out


def run_conformance(cells: Optional[Sequence[Cell]] = None, *,
                    n_log: int = 4096, n_man: int = 4096,
                    quick: bool = False, seed: int = 0) -> Dict:
    """Run the grid; returns {meta, cells: [...]}, JSON-serializable."""
    import jax

    if cells is None:
        cells = default_grid(quick=quick)
    if quick:
        n_log, n_man = min(n_log, 1024), min(n_man, 1024)
    report = {
        "meta": {
            "jax": jax.__version__,
            "numpy": np.__version__,
            "backend": jax.default_backend(),
            "sweep": {"n_log": n_log, "n_man": n_man, "seed": seed},
        },
        "cells": [run_cell(c, n_log=n_log, n_man=n_man, seed=seed)
                  for c in cells],
    }
    return report


def cell_gate(cell_report: Dict) -> bool:
    """Pass/fail verdict for one measured cell.

    Every cell must honor the IEEE edge contract (edge_failures == 0) and
    produce finite ULP statistics; non-ILM cells at n_iters >= 2 must
    additionally deliver the paper's eq. 17 gate (<= 2 max ULP). The
    n=1 @ 12-bit dial point is the deliberately-loose end of the accuracy
    dial and is not ULP-gated.

    Consumer cells (op in {softmax, rmsnorm}) swap the oracle-ULP gate for
    the metrics that isolate the unit's contribution (eval/consumers.py):
    vs-exact-twin integer ULP and, for softmax, row-sum accuracy — the
    shared exp/reduction error dominates oracle ULPs on hard strata in
    every mode including exact, so gating on it would measure the
    consumer, not the divider.
    """
    o = cell_report["overall"]
    ok = cell_report["edge_failures"] == 0 and np.isfinite(o["max_ulp"])
    if cell_report.get("op") in consumers.CONSUMER_OPS:
        if cell_report["mode"] != "ilm" and cell_report["n_iters"] >= 2:
            ok = ok and (cell_report["vs_exact_max_ulp"]
                         <= consumers.VS_EXACT_GATE_ULP)
            if cell_report["op"] == "softmax":
                ok = ok and (cell_report["row_sum_max_ulp1"]
                             <= consumers.ROW_SUM_GATE_ULP)
        return bool(ok)
    if cell_report["mode"] != "ilm" and cell_report["n_iters"] >= 2:
        ok = ok and o["max_ulp"] <= GATE_MAX_ULP
    return bool(ok)


def cell_lookup(report: Dict, **kw) -> Dict:
    """First report cell matching all given field values (mode=, dtype=, ...)."""
    for c in report["cells"]:
        if all(c.get(k) == v for k, v in kw.items()):
            return c
    raise KeyError(f"no cell matching {kw}")


def format_table(report: Dict) -> str:
    """Human-readable mode x schedule x n_iters ULP table."""
    hdr = (f"{'op':5s} {'mode':18s} {'schedule':10s} {'n':>2s} {'bits':>4s} "
           f"{'dtype':9s} {'uflow':7s} {'max_ulp':>10s} {'mean_ulp':>10s} "
           f"{'p99':>8s} {'edges':>5s} {'gate':>5s}")
    lines = [hdr, "-" * len(hdr)]
    for c in report["cells"]:
        o = c["overall"]
        lines.append(
            f"{c['op']:5s} {c['mode']:18s} {c['schedule']:10s} "
            f"{c['n_iters']:2d} {c['precision_bits']:4d} {c['dtype']:9s} "
            f"{c.get('underflow', '-'):7s} "
            f"{o['max_ulp']:10.3f} {o['mean_ulp']:10.4f} {o['p99_ulp']:8.3f} "
            f"{'ok' if c['edge_failures'] == 0 else c['edge_failures']:>5} "
            f"{'pass' if c.get('pass', True) else 'FAIL':>5}")
    return "\n".join(lines)


def _emit(report: Dict, json_path: Optional[str]) -> int:
    """Shared tail of main/fanout: table, optional JSON, pass/fail exit."""
    print(format_table(report))
    if json_path:
        with open(json_path, "w") as f:
            json.dump(report, f, indent=1)
        print(f"# wrote {json_path}")
    failing = [c["key"] for c in report["cells"] if not c.get("pass", True)]
    if failing:
        print(f"# CONFORMANCE FAILURES ({len(failing)} cells):")
        for k in failing:
            print(f"#   {k}")
        return 1
    return 0


def _run_fanout(args, n: int) -> int:
    """Fan the grid out over ``n`` worker subprocesses, one ``--shard i/n``
    each — the grid is embarrassingly parallel by cell.

    Workers re-derive the same deterministic cell list and take the
    interleaved slice ``cells[i::n]``, so the merged report
    (``merged[i::n] = shard_i``) restores the exact single-process cell
    order. Each worker is its own jax process; on this container they share
    the host CPU, on a multi-host fleet the same flag pins one shard per
    process/device. A worker that dies without writing its report fails the
    whole run.
    """
    import os
    import subprocess
    import tempfile

    cmd = [sys.executable, "-m", "repro.eval.conformance",
           "--seed", str(args.seed)]
    if args.quick:
        cmd.append("--quick")
    if args.modes:
        cmd += ["--modes", args.modes]
    src_root = os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_root + os.pathsep + env.get("PYTHONPATH", "")
    with tempfile.TemporaryDirectory() as td:
        paths = [os.path.join(td, f"shard{i}.json") for i in range(n)]
        procs = [subprocess.Popen(cmd + ["--shard", f"{i}/{n}",
                                         "--json", paths[i]],
                                  env=env, stdout=subprocess.DEVNULL)
                 for i in range(n)]
        rcs = [p.wait() for p in procs]
        shards = []
        for i, path in enumerate(paths):
            if not os.path.exists(path):
                print(f"# fanout shard {i}/{n} wrote no report "
                      f"(exit {rcs[i]})")
                return 1
            with open(path) as f:
                shards.append(json.load(f))
    merged: List = [None] * sum(len(s["cells"]) for s in shards)
    for i, s in enumerate(shards):
        merged[i::n] = s["cells"]
    report = {"meta": {**shards[0]["meta"], "fanout": n}, "cells": merged}
    return _emit(report, args.json)


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized sweep (1024-point strata, n=2 dial only)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the machine-readable report here")
    ap.add_argument("--modes", default=None,
                    help="comma-separated mode filter (e.g. taylor,goldschmidt)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--shard", default=None, metavar="K/N",
                    help="run only the interleaved grid slice cells[K::N]")
    ap.add_argument("--fanout", type=int, default=0, metavar="N",
                    help="fan the grid out over N --shard subprocesses and "
                         "merge their reports")
    args = ap.parse_args(argv)
    if args.fanout and args.shard:
        ap.error("--fanout and --shard are mutually exclusive")
    if args.fanout and args.fanout > 1:
        return _run_fanout(args, args.fanout)

    cells = default_grid(quick=args.quick)
    if args.modes:
        from repro.core.division_modes import MODES

        keep = set(args.modes.split(","))
        unknown = keep - set(MODES)
        if unknown:
            ap.error(f"unknown modes {sorted(unknown)}; valid: {MODES}")
        cells = [c for c in cells if c.mode in keep]
    if args.shard:
        try:
            k, n = (int(p) for p in args.shard.split("/"))
        except ValueError:
            ap.error("--shard wants K/N (e.g. 0/8)")
        if not 0 <= k < n:
            ap.error(f"--shard needs 0 <= K < N, got {args.shard}")
        cells = cells[k::n]
    report = run_conformance(cells, quick=args.quick, seed=args.seed)
    return _emit(report, args.json)


if __name__ == "__main__":
    sys.exit(main())
