"""Golden-vector store: committed bit-exact outputs, regressions fail loudly.

A small deterministic operand corpus is pushed through a fixed set of
division-mode cells; the resulting f32 *bit patterns* are committed as an
``.npz`` next to this module. ``check()`` recomputes and compares in integer
ULPs (default tolerance 0 — any numerics change must be deliberate and
regenerate the vectors):

    PYTHONPATH=src python -m repro.eval.golden --check
    PYTHONPATH=src python -m repro.eval.golden --generate   # after a deliberate change

tests/test_conformance.py runs the check in tier-1, so an accidental change
to seeds, schedules, the compensated residual, or the kernels shows up as a
named cell with its ULP drift — not as a silent accuracy loss.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import ulp

__all__ = ["GOLDEN_PATH", "golden_cells", "golden_inputs", "generate", "check"]

GOLDEN_PATH = Path(__file__).parent / "golden" / "reciprocal_v1.npz"


def golden_cells() -> List[Tuple[str, Dict]]:
    """(key, kwargs-for-DivisionConfig + op) pairs covered by the store."""
    cells = [
        ("recip/taylor/paper/n2p24",
         dict(mode="taylor", schedule="paper", n_iters=2, precision_bits=24)),
        ("recip/taylor/factored/n2p24",
         dict(mode="taylor", schedule="factored", n_iters=2, precision_bits=24)),
        ("recip/taylor/factored/n1p12",
         dict(mode="taylor", schedule="factored", n_iters=1, precision_bits=12)),
        ("recip/taylor_pallas/factored/n2p24",
         dict(mode="taylor_pallas", schedule="factored", n_iters=2,
              precision_bits=24)),
        ("recip/goldschmidt/n2p24",
         dict(mode="goldschmidt", n_iters=2, precision_bits=24)),
        ("recip/goldschmidt_pallas/n2p24",
         dict(mode="goldschmidt_pallas", n_iters=2, precision_bits=24)),
        ("recip/ilm/n2p24", dict(mode="ilm", n_iters=2, precision_bits=24)),
        ("div/goldschmidt/n2p24",
         dict(mode="goldschmidt", n_iters=2, precision_bits=24)),
    ]
    return cells


def golden_inputs() -> np.ndarray:
    """Deterministic f32 corpus: logspace + mantissa-dense + IEEE edges."""
    parts = [
        ulp.sweep_logspace(256, "float32", seed=101),
        ulp.sweep_mantissa(96, "float32", seed=102),   # grid+jitter -> 192
        ulp.sweep_edges("float32"),
        ulp.sweep_subnormals(32, "float32", seed=103),
    ]
    return np.concatenate(parts).astype(np.float32)


def golden_numerators(n: int) -> np.ndarray:
    """Deterministic numerator sweep for the div cells (committed alongside
    inputs — RNG streams are not stable across numpy releases)."""
    return ulp.sweep_logspace(n, "float32", seed=104)


def _compute(key: str, kw: Dict, x: np.ndarray, a: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp

    from repro.core.division_modes import DivisionConfig, div, recip

    cfg = DivisionConfig(**kw)
    xj = jnp.asarray(x)
    if key.startswith("div/"):
        out = div(jnp.asarray(a), xj, cfg)
    else:
        out = recip(xj, cfg)
    return np.asarray(out, np.float32)


def generate(path: Path = GOLDEN_PATH) -> Path:
    """Recompute every cell and (over)write the committed vectors."""
    import jax

    x = golden_inputs()
    a = golden_numerators(x.size)
    arrays = {"inputs": x, "numerators": a}
    for key, kw in golden_cells():
        arrays["out:" + key] = _compute(key, kw, x, a).view(np.uint32)
    arrays["meta"] = np.frombuffer(json.dumps({
        "version": 1, "jax": jax.__version__, "numpy": np.__version__,
    }).encode(), np.uint8)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path, **arrays)
    return path


def check(path: Path = GOLDEN_PATH, tolerance_ulp: int = 0) -> List[Dict]:
    """Recompute and diff against the store. Returns failures (empty = pass)."""
    with np.load(path) as z:
        x = z["inputs"]
        a = z["numerators"] if "numerators" in z.files else golden_numerators(x.size)
        stored = {k[len("out:"):]: z[k] for k in z.files if k.startswith("out:")}
    failures: List[Dict] = []
    for key, kw in golden_cells():
        if key not in stored:
            failures.append({"cell": key, "error": "missing from store"})
            continue
        want = stored[key].view(np.float32)
        got = _compute(key, kw, x, a)
        d = ulp.ulp_diff(got, want)
        bad = d > tolerance_ulp
        if bad.any():
            failures.append({
                "cell": key,
                "n_mismatch": int(bad.sum()),
                "max_ulp_drift": int(d.max()),
                "first_input": float(x[np.argmax(d)]),
            })
    return failures


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--generate", action="store_true")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--path", type=Path, default=GOLDEN_PATH)
    ap.add_argument("--tolerance-ulp", type=int, default=0)
    args = ap.parse_args(argv)
    if args.generate:
        p = generate(args.path)
        print(f"wrote {p} ({p.stat().st_size} bytes, "
              f"{len(golden_cells())} cells x {golden_inputs().size} points)")
        return 0
    failures = check(args.path, args.tolerance_ulp)
    if failures:
        print("GOLDEN-VECTOR REGRESSION:")
        for f in failures:
            print(f"  {f}")
        return 1
    print(f"golden vectors ok ({len(golden_cells())} cells, {args.path})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
