"""Golden-vector store: committed bit-exact outputs, regressions fail loudly.

A small deterministic operand corpus is pushed through a fixed set of
division-mode cells; the resulting f32 *bit patterns* are committed as an
``.npz`` next to this module. ``check()`` recomputes and compares in integer
ULPs (default tolerance 0 — any numerics change must be deliberate and
regenerate the vectors):

    PYTHONPATH=src python -m repro.eval.golden --check   # recip+divide+rsqrt+softmax
    PYTHONPATH=src python -m repro.eval.golden --generate   # after a deliberate change
    PYTHONPATH=src python -m repro.eval.golden --check --store softmax

tests/test_conformance.py runs the check in tier-1, so an accidental change
to seeds, schedules, the compensated residual, or the kernels shows up as a
named cell with its ULP drift — not as a silent accuracy loss.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from . import ulp

__all__ = ["GOLDEN_PATH", "DIVIDE_PATH", "RSQRT_PATH", "SOFTMAX_PATH",
           "golden_cells", "golden_inputs", "golden_div_cells",
           "golden_div_inputs", "golden_rsqrt_cells", "golden_rsqrt_inputs",
           "golden_softmax_cells", "golden_softmax_inputs", "generate",
           "generate_divide", "generate_rsqrt", "generate_softmax", "check",
           "check_divide", "check_rsqrt", "check_softmax"]

GOLDEN_PATH = Path(__file__).parent / "golden" / "reciprocal_v1.npz"
DIVIDE_PATH = Path(__file__).parent / "golden" / "divide_v1.npz"
RSQRT_PATH = Path(__file__).parent / "golden" / "rsqrt_v1.npz"
SOFTMAX_PATH = Path(__file__).parent / "golden" / "softmax_v1.npz"


def golden_cells() -> List[Tuple[str, Dict]]:
    """(key, kwargs-for-DivisionConfig + op) pairs covered by the store."""
    cells = [
        ("recip/taylor/paper/n2p24",
         dict(mode="taylor", schedule="paper", n_iters=2, precision_bits=24)),
        ("recip/taylor/factored/n2p24",
         dict(mode="taylor", schedule="factored", n_iters=2, precision_bits=24)),
        ("recip/taylor/factored/n1p12",
         dict(mode="taylor", schedule="factored", n_iters=1, precision_bits=12)),
        ("recip/taylor_pallas/factored/n2p24",
         dict(mode="taylor_pallas", schedule="factored", n_iters=2,
              precision_bits=24)),
        ("recip/goldschmidt/n2p24",
         dict(mode="goldschmidt", n_iters=2, precision_bits=24)),
        ("recip/goldschmidt_pallas/n2p24",
         dict(mode="goldschmidt_pallas", n_iters=2, precision_bits=24)),
        ("recip/ilm/n2p24", dict(mode="ilm", n_iters=2, precision_bits=24)),
        ("div/goldschmidt/n2p24",
         dict(mode="goldschmidt", n_iters=2, precision_bits=24)),
    ]
    return cells


def golden_inputs() -> np.ndarray:
    """Deterministic f32 corpus: logspace + mantissa-dense + IEEE edges."""
    parts = [
        ulp.sweep_logspace(256, "float32", seed=101),
        ulp.sweep_mantissa(96, "float32", seed=102),   # grid+jitter -> 192
        ulp.sweep_edges("float32"),
        ulp.sweep_subnormals(32, "float32", seed=103),
    ]
    return np.concatenate(parts).astype(np.float32)


def golden_numerators(n: int) -> np.ndarray:
    """Deterministic numerator sweep for the div cells (committed alongside
    inputs — RNG streams are not stable across numpy releases)."""
    return ulp.sweep_logspace(n, "float32", seed=104)


def golden_div_cells() -> List[Tuple[str, Dict]]:
    """op=div cells in the divide store: every approximate divide datapath."""
    return [
        ("div/taylor/paper/n2p24",
         dict(mode="taylor", schedule="paper", n_iters=2, precision_bits=24)),
        ("div/taylor/factored/n2p24",
         dict(mode="taylor", schedule="factored", n_iters=2,
              precision_bits=24)),
        ("div/taylor/factored/n1p12",
         dict(mode="taylor", schedule="factored", n_iters=1,
              precision_bits=12)),
        ("div/taylor_pallas/factored/n2p24",
         dict(mode="taylor_pallas", schedule="factored", n_iters=2,
              precision_bits=24)),
        ("div/goldschmidt/n2p24",
         dict(mode="goldschmidt", n_iters=2, precision_bits=24)),
        ("div/goldschmidt_pallas/n2p24",
         dict(mode="goldschmidt_pallas", n_iters=2, precision_bits=24)),
    ]


def golden_div_inputs() -> Tuple[np.ndarray, np.ndarray]:
    """Deterministic f32 (a, b) pair corpus for the divide store.

    Includes the adversarial classes the exponent-separated datapath exists
    for: ratio-representable-but-reciprocal-underflowing pairs, quotients
    straddling the under/overflow cliffs, the full IEEE edge cross product,
    and subnormal operands (FTZ class).
    """
    b_log = ulp.sweep_logspace(192, "float32", seed=201)
    a_log = ulp.sweep_logspace(192, "float32", seed=202)
    a_rx, b_rx = ulp.sweep_ratio_extremes(128, "float32", seed=203)
    a_qe, b_qe = ulp.sweep_quotient_edges(96, "float32", seed=204)
    a_ed, b_ed = ulp.div_edge_pairs("float32")
    b_sub = ulp.sweep_subnormals(32, "float32", seed=205)
    a_sub = ulp.sweep_logspace(32, "float32", seed=206)
    a = np.concatenate([a_log, a_rx, a_qe, a_ed, a_sub]).astype(np.float32)
    b = np.concatenate([b_log, b_rx, b_qe, b_ed, b_sub]).astype(np.float32)
    return a, b


def golden_rsqrt_cells() -> List[Tuple[str, Dict]]:
    """op=rsqrt cells: the Newton dial, mode dispatch, and both underflow
    policies (the subnormal stratum differs between them by design)."""
    return [
        ("rsqrt/taylor/newton2", dict(mode="taylor")),
        ("rsqrt/taylor/newton3", dict(mode="taylor", rsqrt_newton=3)),
        ("rsqrt/goldschmidt/newton2", dict(mode="goldschmidt")),
        ("rsqrt/taylor/newton2/ftz", dict(mode="taylor", underflow="ftz")),
    ]


def golden_rsqrt_inputs() -> np.ndarray:
    """Deterministic f32 rsqrt corpus: positive logspace over both exponent
    parities, mantissa-dense [1, 4), IEEE edges, subnormal operands."""
    parts = [
        np.abs(ulp.sweep_logspace(256, "float32", seed=301)),
        ulp.sweep_exponent_parity(128, "float32", seed=302),
        ulp.sweep_rsqrt_mantissa(96, "float32", seed=303),   # grid+jitter
        ulp.sweep_edges("float32"),
        np.abs(ulp.sweep_subnormals(32, "float32", seed=304)),
    ]
    return np.concatenate(parts).astype(np.float32)


def golden_softmax_cells() -> List[Tuple[str, Dict]]:
    """op=softmax cells: every approximate datapath the dispatch can route
    (jnp twins, both fused-kernel schedules, the ILM emulation)."""
    return [
        ("softmax/taylor/paper/n2p24",
         dict(mode="taylor", schedule="paper", n_iters=2, precision_bits=24)),
        ("softmax/taylor/factored/n2p24",
         dict(mode="taylor", schedule="factored", n_iters=2,
              precision_bits=24)),
        ("softmax/taylor_pallas/factored/n2p24",
         dict(mode="taylor_pallas", schedule="factored", n_iters=2,
              precision_bits=24)),
        ("softmax/goldschmidt/n2p24",
         dict(mode="goldschmidt", n_iters=2, precision_bits=24)),
        ("softmax/goldschmidt_pallas/n2p24",
         dict(mode="goldschmidt_pallas", n_iters=2, precision_bits=24)),
        ("softmax/ilm/n2p24", dict(mode="ilm", n_iters=2, precision_bits=24)),
    ]


def golden_softmax_inputs() -> np.ndarray:
    """Deterministic f32 logit-row corpus (R, 64): the consumer strata
    (gaussian / wide-dynamic-range / denormal-logit / peaked / tied rows,
    eval/consumers.py) plus the edge rows (fully-masked, single-survivor,
    nan-propagation)."""
    from . import consumers

    strata = consumers.softmax_rows("float32", n_rows=24, d=64, seed=401)
    parts = [strata[k] for k in sorted(strata)]
    parts.append(consumers.softmax_edge_rows("float32", d=64))
    return np.concatenate(parts).astype(np.float32)


def _compute(key: str, kw: Dict, x: np.ndarray, a: np.ndarray) -> np.ndarray:
    import jax.numpy as jnp

    from repro.core.division_modes import (DivisionConfig, div, recip, rsqrt,
                                           softmax)

    cfg = DivisionConfig(**kw)
    xj = jnp.asarray(x)
    if key.startswith("div/"):
        out = div(jnp.asarray(a), xj, cfg)
    elif key.startswith("rsqrt/"):
        out = rsqrt(xj, cfg)
    elif key.startswith("softmax/"):
        out = softmax(xj, -1, cfg)
    else:
        out = recip(xj, cfg)
    return np.asarray(out, np.float32)


def generate(path: Path = GOLDEN_PATH) -> Path:
    """Recompute every cell and (over)write the committed vectors."""
    import jax

    x = golden_inputs()
    a = golden_numerators(x.size)
    arrays = {"inputs": x, "numerators": a}
    for key, kw in golden_cells():
        arrays["out:" + key] = _compute(key, kw, x, a).view(np.uint32)
    arrays["meta"] = np.frombuffer(json.dumps({
        "version": 1, "jax": jax.__version__, "numpy": np.__version__,
    }).encode(), np.uint8)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path, **arrays)
    return path


def generate_divide(path: Path = DIVIDE_PATH) -> Path:
    """Recompute every divide cell and (over)write the committed vectors."""
    import jax

    a, b = golden_div_inputs()
    arrays = {"a": a, "b": b}
    for key, kw in golden_div_cells():
        arrays["out:" + key] = _compute(key, kw, b, a).view(np.uint32)
    arrays["meta"] = np.frombuffer(json.dumps({
        "version": 1, "jax": jax.__version__, "numpy": np.__version__,
    }).encode(), np.uint8)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path, **arrays)
    return path


def generate_rsqrt(path: Path = RSQRT_PATH) -> Path:
    """Recompute every rsqrt cell and (over)write the committed vectors."""
    import jax

    x = golden_rsqrt_inputs()
    arrays = {"inputs": x}
    for key, kw in golden_rsqrt_cells():
        arrays["out:" + key] = _compute(key, kw, x, x).view(np.uint32)
    arrays["meta"] = np.frombuffer(json.dumps({
        "version": 1, "jax": jax.__version__, "numpy": np.__version__,
    }).encode(), np.uint8)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path, **arrays)
    return path


def generate_softmax(path: Path = SOFTMAX_PATH) -> Path:
    """Recompute every softmax cell and (over)write the committed vectors."""
    import jax

    x = golden_softmax_inputs()
    arrays = {"inputs": x}
    for key, kw in golden_softmax_cells():
        arrays["out:" + key] = _compute(key, kw, x, x).view(np.uint32)
    arrays["meta"] = np.frombuffer(json.dumps({
        "version": 1, "jax": jax.__version__, "numpy": np.__version__,
    }).encode(), np.uint8)
    path.parent.mkdir(parents=True, exist_ok=True)
    np.savez_compressed(path, **arrays)
    return path


def check_softmax(path: Path = SOFTMAX_PATH,
                  tolerance_ulp: int = 0) -> List[Dict]:
    """Recompute the softmax store and diff. Returns failures (empty = pass)."""
    if not path.exists():
        return [{"cell": "softmax store", "error": f"missing {path} — run "
                 "`python -m repro.eval.golden --generate --store softmax`"}]
    with np.load(path) as z:
        x = z["inputs"]
        stored = {k[len("out:"):]: z[k] for k in z.files if k.startswith("out:")}
    failures: List[Dict] = []
    for key, kw in golden_softmax_cells():
        if key not in stored:
            failures.append({"cell": key, "error": "missing from store"})
            continue
        want = stored[key].view(np.float32)
        got = _compute(key, kw, x, x)
        d = ulp.ulp_diff(got, want)
        bad = d > tolerance_ulp
        if bad.any():
            i = np.unravel_index(int(np.argmax(d)), d.shape)
            failures.append({
                "cell": key,
                "n_mismatch": int(bad.sum()),
                "max_ulp_drift": int(d.max()),
                "first_row_col": tuple(int(j) for j in i),
            })
    return failures


def check_rsqrt(path: Path = RSQRT_PATH, tolerance_ulp: int = 0) -> List[Dict]:
    """Recompute the rsqrt store and diff. Returns failures (empty = pass)."""
    if not path.exists():
        return [{"cell": "rsqrt store", "error": f"missing {path} — run "
                 "`python -m repro.eval.golden --generate --store rsqrt`"}]
    with np.load(path) as z:
        x = z["inputs"]
        stored = {k[len("out:"):]: z[k] for k in z.files if k.startswith("out:")}
    failures: List[Dict] = []
    for key, kw in golden_rsqrt_cells():
        if key not in stored:
            failures.append({"cell": key, "error": "missing from store"})
            continue
        want = stored[key].view(np.float32)
        got = _compute(key, kw, x, x)
        d = ulp.ulp_diff(got, want)
        bad = d > tolerance_ulp
        if bad.any():
            failures.append({
                "cell": key,
                "n_mismatch": int(bad.sum()),
                "max_ulp_drift": int(d.max()),
                "first_input": float(x[np.argmax(d)]),
            })
    return failures


def check_divide(path: Path = DIVIDE_PATH, tolerance_ulp: int = 0) -> List[Dict]:
    """Recompute the divide store and diff. Returns failures (empty = pass)."""
    if not path.exists():
        return [{"cell": "divide store", "error": f"missing {path} — run "
                 "`python -m repro.eval.golden --generate --store divide`"}]
    with np.load(path) as z:
        a, b = z["a"], z["b"]
        stored = {k[len("out:"):]: z[k] for k in z.files if k.startswith("out:")}
    failures: List[Dict] = []
    for key, kw in golden_div_cells():
        if key not in stored:
            failures.append({"cell": key, "error": "missing from store"})
            continue
        want = stored[key].view(np.float32)
        got = _compute(key, kw, b, a)
        d = ulp.ulp_diff(got, want)
        bad = d > tolerance_ulp
        if bad.any():
            i = int(np.argmax(d))
            failures.append({
                "cell": key,
                "n_mismatch": int(bad.sum()),
                "max_ulp_drift": int(d.max()),
                "first_pair": (float(a[i]), float(b[i])),
            })
    return failures


def check(path: Path = GOLDEN_PATH, tolerance_ulp: int = 0) -> List[Dict]:
    """Recompute and diff against the store. Returns failures (empty = pass)."""
    if not path.exists():
        return [{"cell": "reciprocal store", "error": f"missing {path} — run "
                 "`python -m repro.eval.golden --generate --store recip`"}]
    with np.load(path) as z:
        x = z["inputs"]
        a = z["numerators"] if "numerators" in z.files else golden_numerators(x.size)
        stored = {k[len("out:"):]: z[k] for k in z.files if k.startswith("out:")}
    failures: List[Dict] = []
    for key, kw in golden_cells():
        if key not in stored:
            failures.append({"cell": key, "error": "missing from store"})
            continue
        want = stored[key].view(np.float32)
        got = _compute(key, kw, x, a)
        d = ulp.ulp_diff(got, want)
        bad = d > tolerance_ulp
        if bad.any():
            failures.append({
                "cell": key,
                "n_mismatch": int(bad.sum()),
                "max_ulp_drift": int(d.max()),
                "first_input": float(x[np.argmax(d)]),
            })
    return failures


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--generate", action="store_true")
    ap.add_argument("--check", action="store_true")
    ap.add_argument("--store",
                    choices=("recip", "divide", "rsqrt", "softmax", "all"),
                    default="all", help="which committed store(s) to act on")
    ap.add_argument("--tolerance-ulp", type=int, default=0)
    args = ap.parse_args(argv)
    do_recip = args.store in ("recip", "all")
    do_divide = args.store in ("divide", "all")
    do_rsqrt = args.store in ("rsqrt", "all")
    do_softmax = args.store in ("softmax", "all")
    if args.generate:
        if do_recip:
            p = generate()
            print(f"wrote {p} ({p.stat().st_size} bytes, "
                  f"{len(golden_cells())} cells x {golden_inputs().size} points)")
        if do_divide:
            p = generate_divide()
            print(f"wrote {p} ({p.stat().st_size} bytes, "
                  f"{len(golden_div_cells())} cells x "
                  f"{golden_div_inputs()[0].size} pairs)")
        if do_rsqrt:
            p = generate_rsqrt()
            print(f"wrote {p} ({p.stat().st_size} bytes, "
                  f"{len(golden_rsqrt_cells())} cells x "
                  f"{golden_rsqrt_inputs().size} points)")
        if do_softmax:
            p = generate_softmax()
            print(f"wrote {p} ({p.stat().st_size} bytes, "
                  f"{len(golden_softmax_cells())} cells x "
                  f"{golden_softmax_inputs().shape} logit rows)")
        return 0
    failures: List[Dict] = []
    if do_recip:
        failures += check(tolerance_ulp=args.tolerance_ulp)
    if do_divide:
        failures += check_divide(tolerance_ulp=args.tolerance_ulp)
    if do_rsqrt:
        failures += check_rsqrt(tolerance_ulp=args.tolerance_ulp)
    if do_softmax:
        failures += check_softmax(tolerance_ulp=args.tolerance_ulp)
    if failures:
        print("GOLDEN-VECTOR REGRESSION:")
        for f in failures:
            print(f"  {f}")
        return 1
    n = (len(golden_cells()) if do_recip else 0) + (
        len(golden_div_cells()) if do_divide else 0) + (
        len(golden_rsqrt_cells()) if do_rsqrt else 0) + (
        len(golden_softmax_cells()) if do_softmax else 0)
    print(f"golden vectors ok ({n} cells)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
