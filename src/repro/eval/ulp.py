"""ULP-error engine: exact ULP distance vs the f64 oracle, stratified sweeps.

The paper's programmable-accuracy claim (eq. 17) ties (n_iters, seed
precision) to delivered output bits; this module is the measuring stick.
Everything is plain numpy on host — results coming out of jax are converted
first, so the engine has no opinion about how the values were produced.

Two distances, for two jobs:

  * :func:`ulp_error` — fractional ULPs between a finite-precision result and
    the *exact* (f64 oracle) value, measured in ULPs of the result dtype at
    the oracle's magnitude. This is the conformance number ("max 0.5 ulp").
  * :func:`ulp_diff` — integer ULP steps between two same-dtype arrays via
    the monotone ordered-integer map. This is the golden-vector / A-vs-B
    number ("goldschmidt is within 1 ulp of factored-taylor").

Sweeps are stratified because uniform sampling never sees the hard cases:
``logspace`` covers the full exponent range, ``mantissa`` is dense in [1, 2)
(where the PWL segments live), ``boundaries`` straddles the seed-table
segment edges by a few ULPs, and ``edges`` is the IEEE corpus (signed zeros,
infs, nan, subnormals, extremes).
"""
from __future__ import annotations

from typing import Dict, Iterable, Mapping

import numpy as np

__all__ = [
    "DTYPES", "ulp_size", "to_ordered", "ulp_diff", "ulp_error",
    "oracle_mask", "subnormal_mask", "cliff_guard", "overflow_guard",
    "sweep_logspace", "sweep_mantissa",
    "sweep_boundaries", "sweep_edges", "sweep_subnormals", "stratified_sweep",
    "summarize", "sweep_ratio_extremes", "sweep_quotient_edges",
    "div_edge_pairs", "div_sweep", "sweep_rsqrt_mantissa",
    "sweep_exponent_parity", "rsqrt_sweep",
]


def _resolve_dtype(dtype):
    """Accept 'bfloat16' / np.float32 / jnp dtypes; return a numpy dtype."""
    if isinstance(dtype, str) and dtype == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(dtype)


DTYPES = ("float32", "bfloat16")

# (mantissa bits incl. hidden, min normal exponent, max exponent) per format.
_FORMAT = {
    "float16": (11, -14, 15),
    "bfloat16": (8, -126, 127),
    "float32": (24, -126, 127),
    "float64": (53, -1022, 1023),
}


def _fmt(dtype):
    dt = _resolve_dtype(dtype)
    return _FORMAT[dt.name]


def ulp_size(exact: np.ndarray, dtype="float32") -> np.ndarray:
    """ULP of ``dtype`` at the magnitude of ``exact`` (f64), as f64.

    ulp(y) = 2^(max(floor(log2|y|), emin) - (p-1)); the emin clamp makes the
    subnormal range share the smallest-normal ULP (fixed-point spacing).
    """
    p, emin, _ = _fmt(dtype)
    x = np.abs(np.asarray(exact, np.float64))
    frac, e = np.frexp(x)                      # x = frac * 2^e, frac in [0.5,1)
    e = np.where(x == 0, emin + 1, e)          # avoid log of 0; clamped below
    return np.ldexp(1.0, np.maximum(e - 1, emin) - (p - 1))


def to_ordered(x: np.ndarray) -> np.ndarray:
    """Monotone map of IEEE floats to int64 (adjacent floats differ by 1).

    +0 and -0 both map to 0; works for any IEEE format (f16/bf16/f32/f64)
    by viewing the underlying bits.
    """
    x = np.asarray(x)
    int_t = {2: np.int16, 4: np.int32, 8: np.int64}[x.dtype.itemsize]
    bits = x.view(int_t).astype(np.int64)
    mag_mask = np.int64((1 << (x.dtype.itemsize * 8 - 1)) - 1)
    return np.where(bits < 0, -(bits & mag_mask), bits)


def ulp_diff(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Integer ULP steps between same-dtype arrays; nan-vs-nan counts as 0."""
    a, b = np.asarray(a), np.asarray(b)
    if a.dtype != b.dtype:
        raise ValueError(f"dtype mismatch: {a.dtype} vs {b.dtype}")
    d = np.abs(to_ordered(a) - to_ordered(b))
    both_nan = np.isnan(a.astype(np.float64)) & np.isnan(b.astype(np.float64))
    return np.where(both_nan, 0, d)


def oracle_mask(exact: np.ndarray, dtype="float32") -> np.ndarray:
    """Inputs whose exact result is a *normal* finite number in ``dtype``.

    ULP statistics are only well-defined there: results that overflow,
    underflow to subnormal/zero, or are inf/nan get their own edge checks
    (hardware units FTZ in that range, by design — see kernels/common.py).
    """
    p, emin, emax = _fmt(dtype)
    ax = np.abs(np.asarray(exact, np.float64))
    tiny = np.ldexp(1.0, emin)
    # Largest finite: (2 - 2^(1-p)) * 2^emax.
    big = np.ldexp(2.0 - 2.0 ** (1 - p), emax)
    return np.isfinite(ax) & (ax >= tiny) & (ax <= big)


def subnormal_mask(x: np.ndarray, dtype="float32") -> np.ndarray:
    """Finite nonzero values strictly below the smallest normal of ``dtype``.

    Under the gradual-underflow policy these lanes carry exact ULP
    statistics (the bit-level jnp datapath normalizes/rounds them); under
    FTZ they are the flush edge class and stay excluded.
    """
    p, emin, _ = _fmt(dtype)
    ax = np.abs(np.asarray(x, np.float64))
    return np.isfinite(ax) & (ax > 0) & (ax < np.ldexp(1.0, emin))


def overflow_guard(exact: np.ndarray, dtype="float32",
                   ulps: float = 2.0) -> np.ndarray:
    """The overflow half of :func:`cliff_guard` on its own.

    Gradual-underflow cells have no flush cliff at the bottom of the normal
    range — quotients there round into the subnormal lattice and are
    measured — so only the largest-finite cliff needs guard-banding.
    """
    p, emin, emax = _fmt(dtype)
    ax = np.abs(np.asarray(exact, np.float64))
    big = np.ldexp(2.0 - 2.0 ** (1 - p), emax)
    return ax <= big - ulps * np.ldexp(1.0, emax - p + 1)


def cliff_guard(exact: np.ndarray, dtype="float32",
                ulps: float = 2.0) -> np.ndarray:
    """Lanes whose exact magnitude sits more than ``ulps`` ULPs inside the
    normal range's cliffs.

    A unit permitted k ULPs of error may legitimately flush a quotient whose
    exact value lies within k ULPs of the smallest normal (FTZ turns the
    miss into -100% error) or overflow one within k ULPs of the largest
    finite. Those lanes belong to the FTZ/overflow edge class, not the ULP
    statistics; AND this with :func:`oracle_mask` for cliff-straddling
    corpora like ``sweep_quotient_edges``.
    """
    p, emin, emax = _fmt(dtype)
    ax = np.abs(np.asarray(exact, np.float64))
    tiny = np.ldexp(1.0, emin)
    big = np.ldexp(2.0 - 2.0 ** (1 - p), emax)
    return ((ax >= tiny * (1.0 + ulps * 2.0 ** (1 - p)))
            & (ax <= big - ulps * np.ldexp(1.0, emax - p + 1)))


def ulp_error(approx: np.ndarray, exact: np.ndarray, dtype="float32",
              where: np.ndarray | None = None) -> np.ndarray:
    """|approx - exact| in ULPs of ``dtype``, elementwise (f64).

    ``approx`` is the finite-precision result (any float dtype), ``exact``
    the f64 oracle. Masked-out lanes (see oracle_mask) return 0.
    """
    approx64 = np.asarray(approx).astype(np.float64)
    exact64 = np.asarray(exact, np.float64)
    mask = oracle_mask(exact64, dtype) if where is None else where
    with np.errstate(invalid="ignore"):   # inf-inf on masked-out lanes
        err = np.where(mask, np.abs(approx64 - exact64), 0.0)
    return err / ulp_size(exact64, dtype)


# ------------------------------------------------------------------- sweeps

def sweep_logspace(n: int = 4096, dtype="float32", seed: int = 0) -> np.ndarray:
    """Signed log-uniform sweep over the full normal exponent range."""
    p, emin, emax = _fmt(dtype)
    rng = np.random.default_rng(seed)
    e = rng.uniform(emin, emax, n)
    s = rng.choice([-1.0, 1.0], n)
    x = s * np.exp2(e)
    return x.astype(_resolve_dtype(dtype))


def sweep_mantissa(n: int = 4096, dtype="float32", seed: int = 1) -> np.ndarray:
    """Dense coverage of [1, 2): grid + jitter, where the PWL segments live."""
    rng = np.random.default_rng(seed)
    grid = 1.0 + np.arange(n) / n
    jit = 1.0 + rng.random(n)
    return np.concatenate([grid, jit]).astype(_resolve_dtype(dtype))


def sweep_boundaries(boundaries: Iterable[float], dtype="float32",
                     ulps: int = 4) -> np.ndarray:
    """Points straddling each seed-segment boundary by -ulps..+ulps steps."""
    dt = _resolve_dtype(dtype)
    base = np.asarray(list(boundaries), np.float64).astype(dt)
    out = [base]
    lo = np.full_like(base, -np.inf, dtype=dt)
    hi = np.full_like(base, np.inf, dtype=dt)
    up, dn = base, base
    for _ in range(ulps):
        # nextafter is not implemented for bf16 — step via the ordered map.
        up = _nextafter(up, hi)
        dn = _nextafter(dn, lo)
        out += [up.copy(), dn.copy()]
    return np.concatenate(out)


def _nextafter(x, towards):
    try:
        return np.nextafter(x, towards)
    except TypeError:  # ml_dtypes formats
        int_t = {2: np.int16, 4: np.int32}[x.dtype.itemsize]
        bits = x.view(int_t)
        step = np.where(towards.astype(np.float64) > x.astype(np.float64), 1, -1)
        step = np.where(x.astype(np.float64) < 0, -step, step).astype(int_t)
        return (bits + step).view(x.dtype)


def sweep_edges(dtype="float32") -> np.ndarray:
    """IEEE edge corpus: signed zeros/infs, nan, extremes, powers of two."""
    p, emin, emax = _fmt(dtype)
    dt = _resolve_dtype(dtype)
    tiny = np.ldexp(1.0, emin)
    big = np.ldexp(2.0 - 2.0 ** (1 - p), emax)
    vals = [0.0, -0.0, np.inf, -np.inf, np.nan,
            1.0, -1.0, 2.0, -2.0, 0.5, -0.5,
            tiny, -tiny, big, -big,
            np.ldexp(1.0, emin - 1), -np.ldexp(1.0, emin - 1),   # subnormal
            np.ldexp(1.0, emax), -np.ldexp(1.0, emax)]
    vals += [np.ldexp(1.0, e) for e in range(emin, emax, 16)]
    return np.asarray(vals, np.float64).astype(dt)


def sweep_subnormals(n: int = 256, dtype="float32", seed: int = 2) -> np.ndarray:
    """Signed subnormal inputs (reciprocal overflows: the FTZ stratum)."""
    p, emin, _ = _fmt(dtype)
    rng = np.random.default_rng(seed)
    tiny = np.ldexp(1.0, emin)
    x = rng.uniform(np.ldexp(1.0, emin - (p - 1)), tiny, n)
    return (x * rng.choice([-1.0, 1.0], n)).astype(_resolve_dtype(dtype))


def stratified_sweep(dtype="float32", n_log: int = 4096, n_man: int = 4096,
                     boundaries: Iterable[float] | None = None,
                     seed: int = 0) -> Dict[str, np.ndarray]:
    """The standard operand corpus, one array per stratum."""
    strata = {
        "logspace": sweep_logspace(n_log, dtype, seed),
        "mantissa": sweep_mantissa(n_man, dtype, seed + 1),
        "edges": sweep_edges(dtype),
        "subnormals": sweep_subnormals(256, dtype, seed + 2),
    }
    if boundaries is not None:
        strata["boundaries"] = sweep_boundaries(boundaries, dtype)
    return strata


# --------------------------------------------------------------- div sweeps
#
# Divide needs *pairs*: the hard cases are relations between numerator and
# denominator (ratio representable while the intermediate reciprocal is not;
# quotient a few ULPs from the overflow/underflow cliff), which no product of
# independent single-operand sweeps reaches with useful density.

def sweep_ratio_extremes(n: int = 2048, dtype="float32",
                         seed: int = 3) -> tuple[np.ndarray, np.ndarray]:
    """(a, b) with a/b a normal number while 1/b is subnormal or inexact.

    The killer corpus for ``a * recip(b)`` divides: |b| sits within a few
    octaves of 2^emax, so the intermediate reciprocal under/overflows (f32:
    1/b < 2^-126) even though the quotient's exponent is unremarkable. An
    exponent-separated datapath is flat here; the composed one was measured
    at 1.6e7 max ULP.
    """
    p, emin, emax = _fmt(dtype)
    rng = np.random.default_rng(seed)
    dt = _resolve_dtype(dtype)
    # |b| = 2^(eb-1) * [1,2) in [2^(emax-1), 2^(emax+1)) => 1/|b| at or
    # below the smallest normal on every lane: the true recip-underflow class.
    eb = rng.uniform(emax, emax + 1, n)
    # Quotient exponent anywhere representable given ea <= emax.
    eq = rng.uniform(emin + 2, np.minimum(emax - eb, emax) - 1, n)
    b = (rng.choice([-1.0, 1.0], n) * np.exp2(eb)
         * rng.uniform(1.0, 2.0, n) / 2.0).astype(dt)
    a = (rng.choice([-1.0, 1.0], n) * np.exp2(eq + eb)
         * rng.uniform(1.0, 2.0, n) / 2.0).astype(dt)
    return a, b


def sweep_quotient_edges(n: int = 1024, dtype="float32",
                         seed: int = 4) -> tuple[np.ndarray, np.ndarray]:
    """(a, b) whose exact quotient straddles the overflow/underflow cliffs.

    Targets land log-uniformly within one octave on either side of the
    largest-finite and smallest-normal magnitudes; a is chosen as
    round(q_target * b) so the realized ratio stays on target to ~1 ULP.
    Only the representable side contributes ULP statistics (oracle_mask);
    the far side exercises the overflow->inf / FTZ->0 contract.
    """
    p, emin, emax = _fmt(dtype)
    rng = np.random.default_rng(seed)
    dt = _resolve_dtype(dtype)
    half = n // 2
    big = np.ldexp(2.0 - 2.0 ** (1 - p), emax)
    tiny = np.ldexp(1.0, emin)
    targets = np.concatenate([
        big * np.exp2(rng.uniform(-1, 1, half)),      # straddle overflow
        tiny * np.exp2(rng.uniform(-1, 1, n - half)), # straddle underflow
    ]) * rng.choice([-1.0, 1.0], n)
    # Denominators mid-range so a = q*b stays representable for the
    # overflow half (|q| ~ 2^128 needs |b| <~ 1) and the underflow half.
    eb = np.where(np.abs(targets) > 1.0,
                  rng.uniform(emin / 2, -1.0, n),
                  rng.uniform(1.0, emax / 2, n))
    b = (rng.choice([-1.0, 1.0], n) * np.exp2(eb)
         * rng.uniform(1.0, 2.0, n) / 2.0).astype(dt)
    a = (targets * b.astype(np.float64)).astype(dt)
    return a, b


def div_edge_pairs(dtype="float32") -> tuple[np.ndarray, np.ndarray]:
    """Full cross product of the IEEE edge corpus against itself.

    Covers every special-value combination for a/b: +-0/x, x/+-0, 0/0,
    inf/inf, inf/x, x/inf, nan propagation, subnormal operands (the FTZ
    class), and extreme-magnitude normals.
    """
    base = sweep_edges(dtype)
    a = np.repeat(base, base.size)
    b = np.tile(base, base.size)
    return a, b


def div_sweep(dtype="float32", n_log: int = 4096, n_man: int = 4096,
              boundaries: Iterable[float] | None = None,
              seed: int = 0) -> Dict[str, tuple[np.ndarray, np.ndarray]]:
    """The standard divide corpus: one (a, b) pair of arrays per stratum."""
    dt = _resolve_dtype(dtype)
    b_log = sweep_logspace(n_log, dtype, seed)
    a_log = sweep_logspace(n_log, dtype, seed + 7)
    b_man = sweep_mantissa(n_man, dtype, seed + 1)
    a_man = sweep_mantissa(n_man, dtype, seed + 8)[::-1].copy()
    b_sub = sweep_subnormals(256, dtype, seed + 2)
    a_sub = sweep_logspace(b_sub.size, dtype, seed + 9)
    strata: Dict[str, tuple[np.ndarray, np.ndarray]] = {
        "logspace": (a_log, b_log),
        "mantissa": (a_man, b_man),
        "ratio_extremes": sweep_ratio_extremes(2048, dtype, seed + 3),
        "quotient_edges": sweep_quotient_edges(1024, dtype, seed + 4),
        "edges": div_edge_pairs(dtype),
        "subnormals": (a_sub, b_sub),
    }
    if boundaries is not None:
        b_bnd = sweep_boundaries(boundaries, dtype)
        a_bnd = sweep_logspace(b_bnd.size, dtype, seed + 5).astype(dt)
        strata["boundaries"] = (a_bnd[:b_bnd.size], b_bnd)
    return strata


# ------------------------------------------------------------- rsqrt sweeps
#
# rsqrt is a single-operand op, but its hard cases are structured by the
# exponent's *parity* (the datapath splits even/odd exponents onto one seed
# octave) and by the two-octave mantissa domain [1, 4): a corpus that only
# covers [1, 2) never exercises the odd-exponent half of the seed table.

def sweep_rsqrt_mantissa(n: int = 4096, dtype="float32",
                         seed: int = 5) -> np.ndarray:
    """Dense coverage of [1, 2) ∪ [2, 4): grid + jitter over both octaves.

    rsqrt folds its operand onto one reduced interval per exponent *parity*,
    so the mantissa-dense corpus must span two octaves where the reciprocal
    corpus needs one.
    """
    rng = np.random.default_rng(seed)
    half = n // 2
    grid_lo = 1.0 + np.arange(half) / half           # [1, 2)
    grid_hi = 2.0 + 2.0 * np.arange(n - half) / (n - half)   # [2, 4)
    jit = 1.0 + 3.0 * rng.random(n)                  # [1, 4)
    return np.concatenate([grid_lo, grid_hi, jit]).astype(_resolve_dtype(dtype))


def sweep_exponent_parity(n: int = 2048, dtype="float32",
                          seed: int = 6) -> np.ndarray:
    """Positive operands split half even / half odd unbiased exponents.

    The rsqrt exponent is halved (2^e -> 2^-e/2), with the parity bit folded
    into the mantissa domain; this stratum pins both halves of that split
    across the full exponent range, including exact powers of two.
    """
    p, emin, emax = _fmt(dtype)
    rng = np.random.default_rng(seed)
    half = n // 2
    e_even = 2 * rng.integers(emin // 2 + 1, emax // 2, half)
    e_odd = 2 * rng.integers(emin // 2 + 1, emax // 2, n - half) + 1
    e = np.concatenate([e_even, e_odd]).astype(np.float64)
    man = np.concatenate([np.ones(n // 4),                  # exact 2^e
                          1.0 + rng.random(n - n // 4)])    # jittered
    return (man[:n] * np.exp2(e)).astype(_resolve_dtype(dtype))


def rsqrt_sweep(dtype="float32", n_log: int = 4096, n_man: int = 4096,
                seed: int = 0) -> Dict[str, np.ndarray]:
    """The standard rsqrt operand corpus, one array per stratum.

    Positive-only ULP strata (negatives are a nan contract, covered by the
    ``edges`` stratum), plus the subnormal stratum — rsqrt of every positive
    subnormal is a mid-range normal, so under gradual underflow these lanes
    carry exact ULP statistics rather than an FTZ class.
    """
    return {
        "logspace": np.abs(sweep_logspace(n_log, dtype, seed)),
        "exp_parity": sweep_exponent_parity(max(n_log // 2, 16), dtype,
                                            seed + 11),
        "mantissa": sweep_rsqrt_mantissa(n_man, dtype, seed + 12),
        "edges": sweep_edges(dtype),
        "subnormals": np.abs(sweep_subnormals(256, dtype, seed + 13)),
    }


def summarize(errs: np.ndarray, mask: np.ndarray | None = None) -> Dict[str, float]:
    """max/mean/p99 ULP over the oracle-valid lanes."""
    e = np.asarray(errs, np.float64)
    if mask is not None:
        e = e[mask]
    if e.size == 0:
        return {"max_ulp": 0.0, "mean_ulp": 0.0, "p99_ulp": 0.0, "n": 0}
    with np.errstate(invalid="ignore"):   # percentile interpolation with infs
        p99 = float(np.percentile(e, 99))
    return {
        "max_ulp": float(e.max()),
        "mean_ulp": float(e.mean()),
        "p99_ulp": p99,
        "n": int(e.size),
    }
