"""Consumer-conformance corpora and oracles: softmax / rmsnorm row sweeps.

The division unit's flagship consumers (normalization: attention softmax,
RMSNorm) get the same measuring stick the scalar ops have had since PR 1 —
stratified operand corpora, an f64 oracle, and metrics that isolate what the
*unit* contributes from what the surrounding kernel (exp, sum-of-squares)
contributes:

  * vs-f64-oracle fractional ULP stats (informational): dominated by the
    consumer's own transcendental/reduction error on hard strata — an f32
    ``exp`` amplifies argument rounding by |arg|, so wide-dynamic-range rows
    legitimately measure thousands of oracle ULPs *in every mode including
    exact*. Reported per stratum, never gated.
  * vs-exact-twin integer ULP (gated): the same consumer computation with
    ``cfg=EXACT`` shares every exp/sum rounding, so the diff isolates the
    division unit's contribution (reciprocal or rsqrt error plus one final
    multiply). Documented tolerance: ``VS_EXACT_GATE_ULP``.
  * row-sum accuracy (softmax, gated): |sum(row) - 1| in ULP-equivalents of
    1.0 (units of 2^(1-p) for the output dtype). The computed outputs are
    ``ex_i * recip(s)`` with s the sum of the *computed* ex, so the exp
    errors cancel and the row sum isolates the reciprocal:
    |sum - 1| <= recip error (<= 1 ULP) + weighted per-element rounding
    (<= 0.5 ULP) — the non-ILM gate is ``ROW_SUM_GATE_ULP`` = 2.

Strata are chosen for the consumer's hard cases: ``wide_range`` rows push
outputs across the full normal/subnormal probability range, ``denormal``
rows carry logits that are themselves subnormal (the gradual-underflow
operand class), ``peaked``/``ties`` rows pin the one-hot and exactly-uniform
limits, and rmsnorm's ``tiny``/``huge`` rows drive the mean-of-squares to
where eps dominates or the square approaches overflow.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from . import ulp

__all__ = [
    "CONSUMER_OPS", "ROW_SUM_GATE_ULP", "VS_EXACT_GATE_ULP",
    "softmax_rows", "softmax_edge_rows", "softmax_oracle",
    "rmsnorm_rows", "rmsnorm_weight", "rmsnorm_oracle",
    "row_sum_ulp1", "vs_exact_int_ulp",
]

CONSUMER_OPS = ("softmax", "rmsnorm")

# Row sums within 2 ULP-equivalents of 1.0 for every non-ILM mode (the
# acceptance gate): 1 ULP reciprocal error + <= 0.5 ULP weighted rounding.
ROW_SUM_GATE_ULP = 2.0

# Elementwise distance from the cfg=EXACT twin on oracle-normal lanes:
# the unit's recip/rsqrt error (<= 1 ULP) vs the exact op (<= 0.5 / 1.36
# ULP for divide / lax.rsqrt) plus the final multiply roundings.
VS_EXACT_GATE_ULP = 4


def softmax_rows(dtype="float32", n_rows: int = 64, d: int = 128,
                 seed: int = 0) -> Dict[str, np.ndarray]:
    """The stratified softmax logit corpus, one (n_rows, d) array per stratum."""
    rng = np.random.default_rng(seed)
    dt = ulp._resolve_dtype(dtype)
    gaussian = rng.normal(0.0, 4.0, (n_rows, d))
    # Full exp dynamic range: differences up to ~174 push output
    # probabilities from ~1 down through the subnormal lattice to zero.
    wide = rng.uniform(-87.0, 87.0, (n_rows, d))
    # Logits that are themselves subnormal: softmax is ~uniform with
    # sub-ULP differences — the gradual-underflow operand class.
    mag = np.exp2(rng.uniform(-149.0, -126.0, (n_rows, d)))
    denormal = mag * rng.choice([-1.0, 1.0], (n_rows, d))
    # One dominating logit per row: the one-hot limit (survivor ~ 1.0).
    peaked = rng.normal(0.0, 1.0, (n_rows, d))
    peaked[np.arange(n_rows), rng.integers(0, d, n_rows)] += 100.0
    # Exactly-tied rows: softmax must deliver 1/d per element.
    ties = np.repeat(rng.normal(0.0, 10.0, (n_rows, 1)), d, axis=1)
    return {
        "gaussian": gaussian.astype(dt),
        "wide_range": wide.astype(dt),
        "denormal_logits": denormal.astype(dt),
        "peaked": peaked.astype(dt),
        "ties": ties.astype(dt),
    }


def softmax_edge_rows(dtype="float32", d: int = 16) -> np.ndarray:
    """Edge-contract rows: fully-masked (all -inf), single-survivor, nan.

    Row 0 (all -inf) must come out all zeros in every mode (the masked-
    softmax contract — never 0 * recip(0) = nan); row 1 keeps one finite
    logit whose probability must be 1 (within a couple of ULPs) with zeros
    elsewhere; row 2 must propagate nan.
    """
    dt = ulp._resolve_dtype(dtype)
    rows = np.full((3, d), -np.inf)
    rows[1, 0] = 0.5
    rows[2, :] = 1.0
    rows[2, d // 2] = np.nan
    return rows.astype(dt)


def softmax_oracle(x64: np.ndarray) -> np.ndarray:
    """f64 stable softmax over the last axis; fully-masked rows -> zeros."""
    x64 = np.asarray(x64, np.float64)
    m = np.max(x64, axis=-1, keepdims=True)
    m = np.where(np.isfinite(m), m, 0.0)
    ex = np.exp(x64 - m)
    s = np.sum(ex, axis=-1, keepdims=True)
    return ex / np.where(s == 0, 1.0, s)


def rmsnorm_rows(dtype="float32", n_rows: int = 64, d: int = 128,
                 seed: int = 0) -> Dict[str, np.ndarray]:
    """The stratified rmsnorm activation corpus, one (n_rows, d) per stratum."""
    rng = np.random.default_rng(seed + 17)
    dt = ulp._resolve_dtype(dtype)
    gaussian = rng.normal(0.0, 3.0, (n_rows, d))
    # Rows scaled across ~24 octaves either way: the mean-of-squares spans
    # [2^-80, 2^80] while staying far from f32 overflow in the squares.
    scales = np.exp2(rng.uniform(-40.0, 40.0, (n_rows, 1)))
    scaled = rng.normal(0.0, 1.0, (n_rows, d)) * scales
    # Tiny rows where eps dominates mean(x^2): the rsqrt argument is ~eps.
    tiny = rng.normal(0.0, 1.0, (n_rows, d)) * np.exp2(-40.0)
    return {
        "gaussian": gaussian.astype(dt),
        "wide_scale": scaled.astype(dt),
        "eps_dominated": tiny.astype(dt),
    }


def rmsnorm_weight(d: int = 128, seed: int = 0) -> np.ndarray:
    """Deterministic f32 weight vector shared by all rmsnorm strata."""
    return np.random.default_rng(seed + 23).normal(
        1.0, 0.5, (d,)).astype(np.float32)


def rmsnorm_oracle(x64: np.ndarray, w64: np.ndarray,
                   eps: float = 1e-6) -> np.ndarray:
    """f64 RMSNorm over the last axis."""
    x64 = np.asarray(x64, np.float64)
    ss = np.mean(x64 * x64, axis=-1, keepdims=True)
    return x64 / np.sqrt(ss + eps) * np.asarray(w64, np.float64)


def row_sum_ulp1(out, dtype="float32") -> np.ndarray:
    """|sum(row) - 1| per row, in ULP-equivalents of 1.0 for ``dtype``.

    The sum runs in f64 over the finite-precision outputs, so the metric
    carries only the consumer's error, not the measurement's. One
    ULP-equivalent is the spacing just above 1.0: 2^(1-p).
    """
    p, _, _ = ulp._fmt(dtype)
    s = np.sum(np.asarray(out, np.float64), axis=-1)
    return np.abs(s - 1.0) / (2.0 ** (1 - p))


def vs_exact_int_ulp(out, exact_twin, oracle64, dtype="float32") -> int:
    """Max integer ULP steps from the cfg=EXACT twin on oracle-normal lanes.

    Lanes whose exact (f64) result is subnormal/zero/inf are excluded:
    under the kernels' FTZ contract a flushed probability sits an entire
    subnormal range of integer steps from the twin's gradual value, which
    is the underflow policy's business (tests/test_underflow_policy.py),
    not the consumer gate's.
    """
    d = ulp.ulp_diff(np.asarray(out), np.asarray(exact_twin))
    mask = ulp.oracle_mask(np.asarray(oracle64, np.float64), dtype)
    d = np.where(mask, d, 0)
    return int(d.max()) if d.size else 0
