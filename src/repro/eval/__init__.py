"""Division-accuracy conformance subsystem.

  * ``ulp``         — exact ULP distance vs the f64 oracle + stratified sweeps
  * ``golden``      — committed golden-vector store (regressions fail loudly)
  * ``conformance`` — (mode x schedule x n_iters x dtype) grid runner

Entry point: ``PYTHONPATH=src python -m repro.eval.conformance``.
"""
from . import ulp  # noqa: F401
