"""Division-accuracy conformance subsystem.

  * ``ulp``              — exact ULP distance vs the f64 oracle + stratified
    sweeps
  * ``golden``           — committed golden-vector store (regressions fail
    loudly)
  * ``conformance``      — (op x mode x schedule x n_iters x dtype) grid
    runner
  * ``workload_metrics`` — workload-level accuracy (K-Means inertia delta,
    QR orthogonality/reconstruction residuals) for ``repro.workloads``

Entry point: ``PYTHONPATH=src python -m repro.eval.conformance``.
"""
from . import ulp, workload_metrics  # noqa: F401
