"""Workload-level accuracy metrics for the division-consumer workloads.

The ULP machinery in :mod:`repro.eval.ulp` judges the division unit op by
op; this module judges it *through a workload*: how far does K-Means'
objective or a Givens QR drift when every divide goes through an
approximate mode instead of the XLA divider? All metrics are computed in
float64 numpy regardless of the input dtype, so the measurement never adds
error of its own.

  * :func:`relative_delta`          — |approx - exact| / max(|exact|, tiny):
    the clustering-inertia delta between a mode and its XLA-exact twin.
  * :func:`orthogonality_residual`  — ||Q^T Q - I||_F / sqrt(M): how far Q
    drifted off the orthogonal manifold.
  * :func:`reconstruction_residual` — ||Q R - A||_F / ||A||_F.
  * :func:`triangularity_residual`  — ||tril(R, -1)||_F / ||R||_F: how well
    the rotations actually annihilated the subdiagonal (qr_givens returns R
    as computed, not hard-zeroed).
  * :func:`qr_residuals`            — the three QR numbers as one dict, the
    shape recorded per mode in ``BENCH_div.json``.

Consumed by ``tests/test_workloads.py`` (hard accuracy gates per mode) and
``benchmarks/run.py`` (``--only workloads``).
"""
from __future__ import annotations

from typing import Dict

import numpy as np

__all__ = ["relative_delta", "orthogonality_residual",
           "reconstruction_residual", "triangularity_residual",
           "qr_residuals"]


def _f64(x) -> np.ndarray:
    return np.asarray(x).astype(np.float64)


def relative_delta(approx, exact, tiny: float = 1e-30) -> float:
    """max over elements of |approx - exact| / max(|exact|, tiny)."""
    a, e = _f64(approx), _f64(exact)
    return float(np.max(np.abs(a - e) / np.maximum(np.abs(e), tiny)))


def orthogonality_residual(q) -> float:
    """||Q^T Q - I||_F / sqrt(M) — scale-free distance from orthogonality."""
    q = _f64(q)
    m = q.shape[-1]
    gram = q.T @ q
    return float(np.linalg.norm(gram - np.eye(m)) / np.sqrt(m))


def reconstruction_residual(q, r, a) -> float:
    """||Q R - A||_F / ||A||_F."""
    q, r, a = _f64(q), _f64(r), _f64(a)
    denom = np.linalg.norm(a)
    return float(np.linalg.norm(q @ r - a) / max(denom, 1e-30))


def triangularity_residual(r) -> float:
    """||tril(R, -1)||_F / ||R||_F — the un-annihilated subdiagonal mass."""
    r = _f64(r)
    denom = np.linalg.norm(r)
    return float(np.linalg.norm(np.tril(r, -1)) / max(denom, 1e-30))


def qr_residuals(q, r, a) -> Dict[str, float]:
    """All three QR quality numbers for one (Q, R, A) triple."""
    return {
        "orthogonality": orthogonality_residual(q),
        "reconstruction": reconstruction_residual(q, r, a),
        "triangularity": triangularity_residual(r),
    }
