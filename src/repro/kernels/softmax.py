"""Pallas TPU kernel: fused row softmax with the Taylor-series reciprocal.

max/exp/sum/scale in one VMEM-resident pass; the 1/sum is the paper's
division unit (recip_f32_bits) rather than an XLA divide. Rows are blocked;
the reduced dim stays whole inside the block (padded positions are masked to
-inf by the wrapper so they contribute exp(-inf)=0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.seeds import compute_segments
from . import common


def _softmax_kernel(x_ref, o_ref, *, n: int, precision_bits: int, schedule: str):
    x = x_ref[...].astype(jnp.float32)
    xmax = jnp.max(x, axis=-1, keepdims=True)
    # Fully-masked rows (all logits -inf: masked consumers and the wrapper's
    # pad rows) must come out as zeros, not exp(-inf - -inf) = nan; rows
    # with at least one finite logit have s >= exp(0) = 1, so s == 0 is an
    # exact tag for them after the guard below.
    mfin = jnp.where(jnp.isfinite(xmax), xmax, jnp.float32(0.0))
    ex = jnp.exp(x - mfin)
    s = jnp.sum(ex, axis=-1, keepdims=True)
    table = compute_segments(n, precision_bits)
    rs = common.recip_f32_bits(s, table, n, schedule)
    o_ref[...] = jnp.where(s == 0.0, jnp.float32(0.0),
                           ex * rs).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("n_iters", "precision_bits", "schedule",
                                             "block_rows", "interpret"))
def softmax_2d(x, *, n_iters: int = 2, precision_bits: int = 24,
               schedule: str = "factored", block_rows: int = 64,
               interpret: bool = True):
    """Softmax over the last dim of an (M, D) array."""
    m, d = x.shape
    bm = min(block_rows, m)
    grid = (pl.cdiv(m, bm),)
    spec = pl.BlockSpec((bm, d), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_softmax_kernel, n=n_iters, precision_bits=precision_bits,
                          schedule=schedule),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
