"""Shape-generic jit'd wrappers around the Pallas kernels.

Arbitrary-rank inputs are reshaped/padded to the 2D tiled forms the kernels
expect (lane dim multiple of 128, sublane of 8), then cropped back. These are
the entry points ``core.division_modes`` uses for mode="taylor_pallas".

Mesh-aware dispatch: a ``pallas_call`` is not GSPMD-partitionable, so under
plain ``jax.jit`` any sharded operand reaching these wrappers is silently
all-gathered onto every device before the kernel runs. When a mesh is
registered (``repro.sharding.rules.use_mesh`` — the launcher does this), the
rank >= 2 paths instead wrap the tiled kernel launch in ``shard_map`` over
the batch axes (largest divisible prefix of ('pod','data'), see
``rules.batch_partition``): each device launches the kernel on its resident
rows, block specs derive from the *per-shard* shape, and ragged last tiles
are masked against local extents inside the kernel — no all-gather, no
resharding. Code already inside a shard_map body disables this with
``rules.suspend_mesh()``.

On CPU (this container) kernels run with interpret=True; on TPU set
``repro.kernels.ops.INTERPRET = False`` (the launcher does this when
jax.default_backend() == 'tpu').
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ilm as ilm_k
from . import rmsnorm as rmsnorm_k
from . import softmax as softmax_k
from . import tsdiv as tsdiv_k

INTERPRET = jax.default_backend() != "tpu"

# One definition of the f32 tile lattice, shared with the tiled kernels.
_LANE = tsdiv_k.LANE
_SUBLANE = tsdiv_k.SUBLANE


def pallas_applicable(x) -> bool:
    """division_modes guard: kernels handle f32/bf16 with >= 1 total element.

    0-d and 1-element inputs are fine — _to_2d pads them out to one
    (8, 128) tile; only empty arrays fall back to the jnp path.
    """
    return x.dtype in (jnp.float32, jnp.bfloat16) and x.size >= 1


def _to_2d(x):
    """Flatten to (M, N) with N a multiple of 128 and M of 8, padding with ones."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    cols = _LANE
    rows = -(-n // cols)
    rows_p = -(-rows // _SUBLANE) * _SUBLANE
    pad = rows_p * cols - n
    flat = jnp.concatenate([flat, jnp.ones((pad,), flat.dtype)])
    return flat.reshape(rows_p, cols), n


def _from_2d(y, n, shape):
    return y.reshape(-1)[:n].reshape(shape)


def _row_shard_axes(rows: int):
    """(mesh, batch_axes) when the active mesh can shard ``rows`` kernel rows.

    None when no mesh is registered (single-device tests/examples run the
    plain launch unchanged) or when no batch-axis prefix divides the row
    count (the kernel would need ragged *shard* extents, which shard_map
    does not express).
    """
    from repro.sharding import rules as shr

    mesh = shr.active_mesh()
    if mesh is None:
        return None
    axes = shr.batch_partition(mesh, rows)
    n = 1
    for ax in axes:
        n *= mesh.shape[ax]
    if n <= 1:
        return None
    return mesh, axes


def _shard_rows(fn, mesh, axes, n_args: int):
    """shard_map a row-tiled 2D kernel launch: dim 0 sharded over ``axes``.

    The body receives the per-shard (rows/n, N) block and launches the tiled
    kernel on it directly — grid and block specs are recomputed from the
    local shape, so sharded operands stay resident end to end (zero
    collectives; the conformance for this is pinned in
    tests/test_sharded_kernels.py). check_rep=False: the elementwise body
    has no replication for shard_map's checker to track through the
    pallas_call.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    spec = P(axes, None)
    return shard_map(fn, mesh=mesh, in_specs=(spec,) * n_args,
                     out_specs=spec, check_rep=False)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def tsdiv_recip(x, n_iters: int = 2, precision_bits: int = 24,
                schedule: str = "factored"):
    """Kernel reciprocal with analytic VJP (bitcasts bar autodiff):
    d(1/x) = -r^2 dx, reusing the kernel's own r."""
    orig_dtype, shape = x.dtype, x.shape
    if x.size == 0:      # no lanes to launch; keep the shape/dtype contract
        return (1.0 / x).astype(orig_dtype)
    if x.ndim >= 2:
        info = _row_shard_axes(int(np.prod(shape[:-1])))
        if info is not None:
            # Mesh-aware rank >= 2 path: per-shard tiled launches over the
            # native layout (the flatten-pad layout below would interleave
            # rows across shard boundaries). Engaged only when sharding
            # actually applies, so the single-device layout — and its
            # bit-pinned outputs — never changes.
            rows = int(np.prod(shape[:-1]))
            x2 = x.astype(jnp.float32).reshape(rows, shape[-1])
            y = _shard_rows(
                lambda xl: tsdiv_k.tsdiv_recip_tiled_2d(
                    xl, n_iters=n_iters, precision_bits=precision_bits,
                    schedule=schedule, interpret=INTERPRET),
                *info, n_args=1)(x2)
            return y.reshape(shape).astype(orig_dtype)
    x2, n = _to_2d(x.astype(jnp.float32))
    y = tsdiv_k.tsdiv_recip_2d(x2, n_iters=n_iters, precision_bits=precision_bits,
                               schedule=schedule, interpret=INTERPRET)
    return _from_2d(y, n, shape).astype(orig_dtype)


def _recip_fwd(x, n_iters, precision_bits, schedule):
    r = tsdiv_recip(x, n_iters, precision_bits, schedule)
    return r, r


def _recip_bwd(n_iters, precision_bits, schedule, r, g):
    # Edge lanes (r = ±inf at x = 0, which under the kernels' FTZ contract
    # includes subnormal operands flushed to the zero class) get zero
    # gradient, not 0*inf = nan — same contract as the jnp twins'
    # custom_jvp rule (fpparts.jnp_reciprocal).
    rf = jnp.where(jnp.isfinite(r), r, 0.0)
    return (-(g * rf * rf),)


tsdiv_recip.defvjp(_recip_fwd, _recip_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def tsdiv_divide(a, b, n_iters: int = 2, precision_bits: int = 24,
                 schedule: str = "factored"):
    """Fused exponent-separated divide kernel with analytic VJP.

    The primal is one kernel launch (no recip+multiply composition); the
    reciprocal kernel runs only on the backward pass to supply 1/b for
    d(a/b) = da/b - q*db/b. Operands must be pre-broadcast to equal shapes
    (division_modes.div does this): broadcasting inside a custom_vjp primal
    would desync the cotangent shapes, and silently flattening unequal
    shapes truncated to a's size.
    """
    if a.shape != b.shape:
        raise ValueError(
            f"tsdiv_divide requires equal shapes, got {a.shape} vs "
            f"{b.shape}; broadcast the operands first")
    orig_dtype, shape = a.dtype, a.shape
    if a.size == 0:      # no lanes to launch; keep the shape/dtype contract
        return jnp.divide(a, b).astype(orig_dtype)
    if a.ndim >= 2:
        # Rank >= 2 operands (distance planes, centroid sums, activation
        # planes — batched or not) stream through the tiled kernel: leading
        # dims collapse row-major into the sublane axis (a metadata-only
        # reshape, no copy), then a 2D grid with ragged last tiles masked
        # in-kernel — no pad copies on the way in or crop on the way out.
        # With an active mesh the launch goes through shard_map so sharded
        # operands stay resident (see module docstring).
        rows = int(np.prod(shape[:-1]))
        a2 = a.astype(jnp.float32).reshape(rows, shape[-1])
        b2 = b.astype(jnp.float32).reshape(rows, shape[-1])

        def launch(al, bl):
            return tsdiv_k.tsdiv_divide_tiled_2d(
                al, bl, n_iters=n_iters, precision_bits=precision_bits,
                schedule=schedule, interpret=INTERPRET)

        info = _row_shard_axes(rows)
        if info is not None:
            launch = _shard_rows(launch, *info, n_args=2)
        return launch(a2, b2).reshape(shape).astype(orig_dtype)
    # Rank 0/1 keeps the flatten-pad path deliberately: a vector laid out as
    # (1, N) in the tiled kernel would occupy one of eight sublanes per tile,
    # while _to_2d packs it (ceil(n/128), 128) at full utilization — the
    # conformance sweeps are exactly such rank-1 operands.
    a2, n = _to_2d(a.astype(jnp.float32))
    b2, _ = _to_2d(b.astype(jnp.float32))
    y = tsdiv_k.tsdiv_divide_2d(a2, b2, n_iters=n_iters,
                                precision_bits=precision_bits,
                                schedule=schedule, interpret=INTERPRET)
    return _from_2d(y, n, shape).astype(orig_dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def tsdiv_rsqrt(x, newton_iters: int = 2, n_segments: int = 16):
    """Fused full-edge rsqrt kernel with analytic VJP (bitcasts bar autodiff):
    d(x^-1/2) = -r^3/2 dx, reusing the kernel's own r. The
    mode="taylor_pallas"/"goldschmidt_pallas" path of division_modes.rsqrt."""
    orig_dtype, shape = x.dtype, x.shape
    if x.size == 0:      # no lanes to launch; keep the shape/dtype contract
        return jax.lax.rsqrt(x.astype(jnp.float32)).astype(orig_dtype)
    if x.ndim >= 2:
        info = _row_shard_axes(int(np.prod(shape[:-1])))
        if info is not None:
            # Same rationale as tsdiv_recip: shard the native (rows, N)
            # layout, per-shard tiled launches; only engaged under a mesh.
            rows = int(np.prod(shape[:-1]))
            x2 = x.astype(jnp.float32).reshape(rows, shape[-1])
            y = _shard_rows(
                lambda xl: tsdiv_k.tsdiv_rsqrt_tiled_2d(
                    xl, newton_iters=newton_iters, n_segments=n_segments,
                    interpret=INTERPRET),
                *info, n_args=1)(x2)
            return y.reshape(shape).astype(orig_dtype)
    x2, n = _to_2d(x.astype(jnp.float32))
    y = tsdiv_k.tsdiv_rsqrt_2d(x2, newton_iters=newton_iters,
                               n_segments=n_segments, interpret=INTERPRET)
    return _from_2d(y, n, shape).astype(orig_dtype)


def _rsqrt_fwd(x, newton_iters, n_segments):
    r = tsdiv_rsqrt(x, newton_iters, n_segments)
    return r, r


def _rsqrt_bwd(newton_iters, n_segments, r, g):
    # Same contract as the jnp twin's custom_jvp rule (fpparts.jnp_rsqrt):
    # edge lanes (r = ±inf/nan) and lanes whose analytic -r^3/2 overflows
    # f32 get zero gradient, never nan poison.
    rf = jnp.where(jnp.isfinite(r), r, 0.0)
    coeff = jnp.float32(-0.5) * rf * rf * rf
    coeff = jnp.where(jnp.isfinite(coeff), coeff, 0.0)
    return (g * coeff,)


tsdiv_rsqrt.defvjp(_rsqrt_fwd, _rsqrt_bwd)


def _divide_fwd(a, b, n_iters, precision_bits, schedule):
    q = tsdiv_divide(a, b, n_iters, precision_bits, schedule)
    return q, (q, b)


def _divide_bwd(n_iters, precision_bits, schedule, res, g):
    q, b = res
    rb = tsdiv_recip(b, n_iters, precision_bits, schedule)
    # Mask edge lanes to zero gradient, as the jnp twins' custom_jvp
    # rule (fpparts.jnp_divide) does. Under the kernels' FTZ contract this
    # covers the subnormal lanes too: a subnormal b is the zero class, so
    # q and rb come back ±inf there and the whole lane is masked rather
    # than poisoned with 0*inf = nan.
    rb = jnp.where(jnp.isfinite(rb), rb, 0.0)
    qf = jnp.where(jnp.isfinite(q), q, 0.0)
    return (g * rb, -(g * qf * rb))


tsdiv_divide.defvjp(_divide_fwd, _divide_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def rmsnorm(x, w, eps: float = 1e-6, newton_iters: int = 2,
            n_segments: int = 16):
    """RMSNorm over the last dim of any (..., D) array.

    Analytic VJP (the pallas_call body bars autodiff): with
    r = rsqrt(mean(x^2) + eps), dx = r*w*g - (r^3/D) * x * sum(g*x*w) and
    dw = sum_batch(g * x * r) — the backward runs in plain jnp.
    """
    shape = x.shape
    d = shape[-1]
    d_pad = -(-d // _LANE) * _LANE
    x2 = x.reshape(-1, d)
    m = x2.shape[0]
    m_pad = -(-m // _SUBLANE) * _SUBLANE
    x2 = jnp.pad(x2, ((0, m_pad - m), (0, d_pad - d)))
    wp = jnp.pad(w, (0, d_pad - d))
    y = rmsnorm_k.rmsnorm_2d(x2, wp, eps=eps, newton_iters=newton_iters,
                             n_segments=n_segments, d_real=d,
                             interpret=INTERPRET)
    return y[:m, :d].reshape(shape)


def _rmsnorm_fwd(x, w, eps, newton_iters, n_segments):
    return rmsnorm(x, w, eps, newton_iters, n_segments), (x, w)


def _rmsnorm_bwd(eps, newton_iters, n_segments, res, g):
    x, w = res
    xf, wf, gf = (t.astype(jnp.float32) for t in (x, w, g))
    d = x.shape[-1]
    r = jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True)
                      + jnp.float32(eps))
    inner = jnp.sum(gf * xf * wf, axis=-1, keepdims=True)
    gx = r * wf * gf - (r * r * r / d) * xf * inner
    gw = jnp.sum(gf * xf * r, axis=tuple(range(x.ndim - 1)))
    return gx.astype(x.dtype), gw.astype(w.dtype)


rmsnorm.defvjp(_rmsnorm_fwd, _rmsnorm_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def softmax(x, n_iters: int = 2, precision_bits: int = 24,
            schedule: str = "factored"):
    """Softmax over the last dim of any (..., D) array (pad masked to -inf).

    Analytic VJP: dx = p * (g - sum(p*g)) reusing the kernel's own output
    (fully-masked rows carry p = 0, so their gradient is exactly zero).
    """
    shape = x.shape
    d = shape[-1]
    d_pad = -(-d // _LANE) * _LANE
    x2 = x.reshape(-1, d)
    m = x2.shape[0]
    m_pad = -(-m // _SUBLANE) * _SUBLANE
    x2 = jnp.pad(x2, ((0, m_pad - m), (0, d_pad - d)),
                 constant_values=-np.inf)
    y = softmax_k.softmax_2d(x2, n_iters=n_iters, precision_bits=precision_bits,
                             schedule=schedule, interpret=INTERPRET)
    return y[:m, :d].reshape(shape)


def _softmax_fwd(x, n_iters, precision_bits, schedule):
    p = softmax(x, n_iters, precision_bits, schedule)
    return p, p


def _softmax_bwd(n_iters, precision_bits, schedule, p, g):
    pf = p.astype(jnp.float32)
    pf = jnp.where(jnp.isfinite(pf), pf, 0.0)    # nan rows: masked gradient
    gf = g.astype(jnp.float32)
    dot = jnp.sum(pf * gf, axis=-1, keepdims=True)
    return ((pf * (gf - dot)).astype(p.dtype),)


softmax.defvjp(_softmax_fwd, _softmax_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_attention(q, k, v, causal: bool = True, block_q: int = 128,
                    block_k: int = 128, n_iters: int = 2,
                    precision_bits: int = 24, schedule: str = "factored"):
    """Flash attention with tsdiv softmax. q/k/v: (..., S, hd); leading dims
    flattened to the batch*heads grid axis.

    Ragged sequence lengths (any sq/sk, not just block multiples) are
    handled here: q is padded up to a block_q multiple (the padded rows are
    sliced off the output), k/v up to a block_k multiple with the padded key
    positions masked to NEG_INF in-kernel (``sk_real``) so they contribute
    exp(NEG_INF - m) = 0 to every real row's statistics.

    Analytic VJP: the forward is the fused kernel; the backward recomputes
    the score matrix in plain jnp (the standard attention gradient — O(S^2)
    memory, vs the O(S) forward; a fused backward kernel is future work).
    """
    from . import flash_attention as fa

    lead = q.shape[:-2]
    s, hd = q.shape[-2], q.shape[-1]
    q3 = q.reshape(-1, s, hd)
    k3 = k.reshape(-1, k.shape[-2], hd)
    v3 = v.reshape(-1, v.shape[-2], hd)
    sk = k3.shape[1]
    bq, bk = min(block_q, s), min(block_k, sk)
    sq_pad = -(-s // bq) * bq
    sk_pad = -(-sk // bk) * bk
    if sq_pad != s:
        q3 = jnp.pad(q3, ((0, 0), (0, sq_pad - s), (0, 0)))
    if sk_pad != sk:
        k3 = jnp.pad(k3, ((0, 0), (0, sk_pad - sk), (0, 0)))
        v3 = jnp.pad(v3, ((0, 0), (0, sk_pad - sk), (0, 0)))
    o = fa.flash_attention(q3, k3, v3, causal=causal, block_q=bq,
                           block_k=bk, n_iters=n_iters,
                           precision_bits=precision_bits, schedule=schedule,
                           sk_real=sk, interpret=INTERPRET)
    return o[:, :s, :].reshape(*lead, s, hd)


def _flash_fwd(q, k, v, causal, block_q, block_k, n_iters, precision_bits,
               schedule):
    o = flash_attention(q, k, v, causal, block_q, block_k, n_iters,
                        precision_bits, schedule)
    return o, (q, k, v)


def _flash_bwd(causal, block_q, block_k, n_iters, precision_bits, schedule,
               res, g):
    from . import flash_attention as fa

    q, k, v = res
    qf, kf, vf, gf = (t.astype(jnp.float32) for t in (q, k, v, g))
    scale = jnp.float32(1.0 / np.sqrt(q.shape[-1]))
    s = jnp.einsum("...qh,...kh->...qk", qf, kf) * scale
    if causal:
        mask = (jnp.arange(s.shape[-2])[:, None]
                >= jnp.arange(s.shape[-1])[None, :])
        s = jnp.where(mask, s, fa.NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    dv = jnp.einsum("...qk,...qh->...kh", p, gf)
    dp = jnp.einsum("...qh,...kh->...qk", gf, vf)
    ds = p * (dp - jnp.sum(dp * p, axis=-1, keepdims=True))
    dq = jnp.einsum("...qk,...kh->...qh", ds, kf) * scale
    dk = jnp.einsum("...qk,...qh->...kh", ds, qf) * scale
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


flash_attention.defvjp(_flash_fwd, _flash_bwd)


def ilm_mul(a, b, *, iters: int = 16):
    shape = a.shape
    a2, n = _to_2d(a.astype(jnp.uint32))
    b2, _ = _to_2d(b.astype(jnp.uint32))
    y = ilm_k.ilm_mul_2d(a2, b2, iters=iters, interpret=INTERPRET)
    return _from_2d(y, n, shape)


def ilm_square(a, *, iters: int = 16):
    shape = a.shape
    a2, n = _to_2d(a.astype(jnp.uint32))
    y = ilm_k.ilm_square_2d(a2, iters=iters, interpret=INTERPRET)
    return _from_2d(y, n, shape)
