"""Pallas TPU kernel: fused Taylor-series reciprocal / divide (the paper's unit).

Three refinement schedules share the datapath (see kernels/common.py):
"paper" (§6 powering), "factored" (log-depth squarings), and "goldschmidt"
(N += N*r residual-register recurrence — the rival algorithm of
arXiv:1909.10154 fused into the same VMEM-resident kernel).

Elementwise over 2D-tiled blocks resident in VMEM. The whole division unit —
unpack, PWL seed ladder, series refinement, repack — is one fused VPU kernel:
a single HBM read and write per element, vs. read/write per stage if composed
from jnp ops without fusion. Block shape defaults to (256, 256) f32 = 256 KiB
in + 256 KiB out, comfortably inside the ~16 MiB/core VMEM with double
buffering; the lane dim is a multiple of 128 (VREG lane width) and the
sublane dim a multiple of 8.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.seeds import SeedTable, compute_segments
from . import common

DEFAULT_BLOCK = (256, 256)

# f32 hardware tile lattice: VREG lane width x sublane count. ops.py's
# shape-generic wrappers pad to the same lattice (imported from here).
SUBLANE = 8
LANE = 128


def _recip_kernel(x_ref, o_ref, *, table: SeedTable, n: int, schedule: str):
    o_ref[...] = common.recip_f32_bits(x_ref[...], table, n, schedule)


def _divide_kernel(a_ref, b_ref, o_ref, *, table: SeedTable, n: int, schedule: str):
    o_ref[...] = common.divide_f32_bits(a_ref[...], b_ref[...], table, n, schedule)


def _rsqrt_kernel(x_ref, o_ref, *, table: SeedTable, newton_iters: int):
    o_ref[...] = common.rsqrt_f32_bits(x_ref[...], table, newton_iters)


def _grid_spec(shape, block):
    bm, bn = min(block[0], shape[0]), min(block[1], shape[1])
    grid = (pl.cdiv(shape[0], bm), pl.cdiv(shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return grid, spec


def _tiled_grid_spec(shape, block):
    """2D grid over an arbitrary (M, N): blocks capped at the array but kept
    on the (8, 128) f32 tile lattice, ragged last tiles included.

    Unlike :func:`_grid_spec` (which assumes the wrappers pre-padded the
    operands to block multiples), this accepts any M, N >= 1: the grid is
    ``cdiv`` in both dims and the last row/column of blocks simply hangs off
    the array edge — Pallas pads the out-of-range reads and drops the
    out-of-range writes; the kernel masks the dead lanes (see
    ``_divide_tiled_kernel``) so no garbage operand ever enters the divide
    datapath.
    """
    bm = min(block[0], -(-shape[0] // SUBLANE) * SUBLANE)
    bn = min(block[1], -(-shape[1] // LANE) * LANE)
    grid = (pl.cdiv(shape[0], bm), pl.cdiv(shape[1], bn))
    spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    return grid, spec, (bm, bn)


def _tile_valid_mask(shape, block):
    """(bm, bn) bool mask of lanes inside the (M, N) array for this tile."""
    i, j = pl.program_id(0), pl.program_id(1)
    bm, bn = block
    rows = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 0) + i * bm
    cols = jax.lax.broadcasted_iota(jnp.int32, (bm, bn), 1) + j * bn
    return (rows < shape[0]) & (cols < shape[1])


def _recip_tiled_kernel(x_ref, o_ref, *, table: SeedTable, n: int,
                        schedule: str, shape, block):
    """Fused reciprocal over one ragged (bm, bn) tile; dead lanes -> 1.0."""
    valid = _tile_valid_mask(shape, block)
    x = jnp.where(valid, x_ref[...], jnp.float32(1.0))
    o_ref[...] = common.recip_f32_bits(x, table, n, schedule)


def _rsqrt_tiled_kernel(x_ref, o_ref, *, table: SeedTable, newton_iters: int,
                        shape, block):
    """Fused full-edge rsqrt over one ragged (bm, bn) tile; dead lanes -> 1.0."""
    valid = _tile_valid_mask(shape, block)
    x = jnp.where(valid, x_ref[...], jnp.float32(1.0))
    o_ref[...] = common.rsqrt_f32_bits(x, table, newton_iters)


def _divide_tiled_kernel(a_ref, b_ref, o_ref, *, table: SeedTable, n: int,
                         schedule: str, shape, block):
    """Fused divide over one (bm, bn) tile of a ragged (M, N) operand pair.

    Lanes past the array edge (last-tile remainder rows/columns) are forced
    to the benign pair 1/1 before the datapath runs: the padded reads are
    implementation-defined, and while their quotients would be discarded on
    store anyway, masking keeps the kernel deterministic.
    """
    valid = _tile_valid_mask(shape, block)
    one = jnp.float32(1.0)
    a = jnp.where(valid, a_ref[...], one)
    b = jnp.where(valid, b_ref[...], one)
    o_ref[...] = common.divide_f32_bits(a, b, table, n, schedule)


@functools.partial(jax.jit, static_argnames=("n_iters", "precision_bits", "schedule",
                                             "block", "interpret"))
def tsdiv_recip_2d(x, *, n_iters: int = 2, precision_bits: int = 24,
                   schedule: str = "factored", block=DEFAULT_BLOCK,
                   interpret: bool = True):
    """Reciprocal of an f32 (M, N) array via the fused division-unit kernel."""
    table = compute_segments(n_iters, precision_bits)
    grid, spec = _grid_spec(x.shape, block)
    return pl.pallas_call(
        functools.partial(_recip_kernel, table=table, n=n_iters, schedule=schedule),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("newton_iters", "n_segments",
                                             "block", "interpret"))
def tsdiv_rsqrt_2d(x, *, newton_iters: int = 2, n_segments: int = 16,
                   block=DEFAULT_BLOCK, interpret: bool = True):
    """rsqrt of an f32 (M, N) array via the fused full-edge rsqrt kernel.

    The mode="taylor_pallas"/"goldschmidt_pallas" rsqrt datapath: PWL chord
    seed + Newton with the residual-compensated final step, FTZ edge
    contract in-kernel (``common.rsqrt_f32_bits``) — what
    ``kernels.ops.tsdiv_rsqrt`` launches for ``division_modes.rsqrt``.
    """
    from repro.core.seeds import rsqrt_seed_table

    table = rsqrt_seed_table(n_segments)
    grid, spec = _grid_spec(x.shape, block)
    return pl.pallas_call(
        functools.partial(_rsqrt_kernel, table=table, newton_iters=newton_iters),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("n_iters", "precision_bits", "schedule",
                                             "block", "interpret"))
def tsdiv_divide_2d(a, b, *, n_iters: int = 2, precision_bits: int = 24,
                    schedule: str = "factored", block=DEFAULT_BLOCK,
                    interpret: bool = True):
    """a / b elementwise: the fused exponent-separated divide datapath.

    schedule="goldschmidt" runs the joint N/D refinement in-kernel (the
    numerator rides the F-multiplies); the Taylor schedules run the mantissa
    series with the Markstein-corrected final multiply (Fig. 7's full-width
    multiplier). Either way the quotient is accurate wherever a/b is
    representable — no intermediate reciprocal to under/overflow.
    """
    table = compute_segments(n_iters, precision_bits)
    grid, spec = _grid_spec(a.shape, block)
    return pl.pallas_call(
        functools.partial(_divide_kernel, table=table, n=n_iters, schedule=schedule),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("n_iters", "precision_bits", "schedule",
                                             "block", "interpret"))
def tsdiv_divide_tiled_2d(a, b, *, n_iters: int = 2, precision_bits: int = 24,
                          schedule: str = "factored", block=DEFAULT_BLOCK,
                          interpret: bool = True):
    """a / b over an arbitrary f32 (M, N) array — the streaming form.

    Same fused exponent-separated datapath as :func:`tsdiv_divide_2d`, but
    grid-scheduled directly over the native 2D layout: no flatten, no
    pre-padding copies. Large batched operands (distance matrices, centroid
    sums, whole activation planes) stream through VMEM one (bm, bn) tile at
    a time; non-multiple-of-block shapes are handled by ragged last tiles
    whose dead lanes are masked in-kernel. This is the path
    ``kernels.ops.tsdiv_divide`` takes for rank-2 operands.
    """
    table = compute_segments(n_iters, precision_bits)
    grid, spec, blk = _tiled_grid_spec(a.shape, block)
    return pl.pallas_call(
        functools.partial(_divide_tiled_kernel, table=table, n=n_iters,
                          schedule=schedule, shape=a.shape, block=blk),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=interpret,
    )(a, b)


@functools.partial(jax.jit, static_argnames=("n_iters", "precision_bits",
                                             "schedule", "block", "interpret"))
def tsdiv_recip_tiled_2d(x, *, n_iters: int = 2, precision_bits: int = 24,
                         schedule: str = "factored", block=DEFAULT_BLOCK,
                         interpret: bool = True):
    """Reciprocal over an arbitrary f32 (M, N) array — the streaming form.

    The unary twin of :func:`tsdiv_divide_tiled_2d`: grid-scheduled over the
    native layout with ragged last tiles masked in-kernel (dead lanes get the
    benign operand 1.0). This is what the mesh-aware dispatch launches per
    shard — the per-shard extents are whatever ``x.shape`` says, so ragged
    masking is automatically against *local* extents.
    """
    table = compute_segments(n_iters, precision_bits)
    grid, spec, blk = _tiled_grid_spec(x.shape, block)
    return pl.pallas_call(
        functools.partial(_recip_tiled_kernel, table=table, n=n_iters,
                          schedule=schedule, shape=x.shape, block=blk),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x)


@functools.partial(jax.jit, static_argnames=("newton_iters", "n_segments",
                                             "block", "interpret"))
def tsdiv_rsqrt_tiled_2d(x, *, newton_iters: int = 2, n_segments: int = 16,
                         block=DEFAULT_BLOCK, interpret: bool = True):
    """rsqrt over an arbitrary f32 (M, N) array — the streaming form.

    Same full-edge FTZ datapath as :func:`tsdiv_rsqrt_2d` but grid-scheduled
    directly over the native 2D layout with ragged last tiles masked
    in-kernel, so per-shard operands of any local extent launch without
    pre-padding copies. The mesh-aware rank >= 2 path of
    ``kernels.ops.tsdiv_rsqrt``.
    """
    from repro.core.seeds import rsqrt_seed_table

    table = rsqrt_seed_table(n_segments)
    grid, spec, blk = _tiled_grid_spec(x.shape, block)
    return pl.pallas_call(
        functools.partial(_rsqrt_tiled_kernel, table=table,
                          newton_iters=newton_iters, shape=x.shape, block=blk),
        grid=grid,
        in_specs=[spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        interpret=interpret,
    )(x)
