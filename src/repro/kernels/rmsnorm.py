"""Pallas TPU kernel: fused RMSNorm with Taylor/Newton rsqrt (beyond-paper).

One block = (bm rows, full feature dim) so the row reduction stays in VMEM:
mean(x^2) -> PWL-seeded Newton rsqrt -> scale, one HBM round trip instead of
the 3+ an unfused norm costs (read x, write sq-sum, read back, write out).
Feature dim d is padded to a multiple of 128 by the wrapper; bm chosen so
bm*d*4B stays well under VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.seeds import rsqrt_seed_table
from . import common


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float, newton_iters: int,
                    n_segments: int, d_real: int):
    x = x_ref[...].astype(jnp.float32)
    # padded tail (if any) contributes zeros; divide by the *real* dim
    ss = jnp.sum(x * x, axis=-1, keepdims=True) * jnp.float32(1.0 / d_real)
    table = rsqrt_seed_table(n_segments)
    se = ss + jnp.float32(eps)
    r = common.rsqrt_f32(se, table, newton_iters)
    # rsqrt_f32 assumes strictly-positive normal input; pin the row edge
    # classes the reduction can produce: nan rows propagate, overflowing
    # sum-of-squares rows scale by rsqrt(inf) = 0 (as lax.rsqrt does).
    r = jnp.where(jnp.isinf(se), jnp.float32(0.0), r)
    r = jnp.where(jnp.isnan(se), jnp.float32(jnp.nan), r)
    o_ref[...] = (x * r * w_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "newton_iters", "n_segments",
                                             "block_rows", "d_real", "interpret"))
def rmsnorm_2d(x, w, *, eps: float = 1e-6, newton_iters: int = 2,
               n_segments: int = 16, block_rows: int = 64, d_real: int | None = None,
               interpret: bool = True):
    """RMSNorm over the last dim of (M, D) x with weight w (D,)."""
    m, d = x.shape
    d_real = d if d_real is None else d_real
    bm = min(block_rows, m)
    grid = (pl.cdiv(m, bm),)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps, newton_iters=newton_iters,
                          n_segments=n_segments, d_real=d_real),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, w)
