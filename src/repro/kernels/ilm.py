"""Pallas TPU kernel: Iterative Logarithmic Multiplier on uint32 lanes.

The bit-exact hardware model (paper §4-5) as a vector kernel: the priority
encoder is a bit-smear + population count, the LOD residue is a subtract, the
shifts are lane-local. Operands must be < 2^16 so every partial product fits
the uint32 lane. ``iters`` unrolls at trace time (it is the paper's accuracy
dial — each unrolled stage is one hardware pipeline stage).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 256)


def _floor_log2(v):
    for s in (1, 2, 4, 8, 16):
        v = v | (v >> s)
    return jax.lax.population_count(v) - jnp.uint32(1)


def _ilm_mul_kernel(a_ref, b_ref, o_ref, *, iters: int):
    a = a_ref[...]
    b = b_ref[...]
    acc = jnp.zeros_like(a)
    one = jnp.uint32(1)
    for _ in range(iters):
        valid = (a > 0) & (b > 0)
        k1 = _floor_log2(jnp.maximum(a, one))
        k2 = _floor_log2(jnp.maximum(b, one))
        ra = a - (one << k1)
        rb = b - (one << k2)
        p = (one << (k1 + k2)) + (ra << k2) + (rb << k1)
        acc = jnp.where(valid, acc + p, acc)
        a = jnp.where(valid, ra, a)
        b = jnp.where(valid, rb, b)
    o_ref[...] = acc


def _ilm_square_kernel(a_ref, o_ref, *, iters: int):
    a = a_ref[...]
    acc = jnp.zeros_like(a)
    one = jnp.uint32(1)
    for _ in range(iters):
        valid = a > 0
        k = _floor_log2(jnp.maximum(a, one))
        r = a - (one << k)
        acc = jnp.where(valid, acc + (one << (k + k)) + (r << (k + one)), acc)
        a = jnp.where(valid, r, a)
    o_ref[...] = acc


def _grid_spec(shape, block):
    bm, bn = min(block[0], shape[0]), min(block[1], shape[1])
    grid = (pl.cdiv(shape[0], bm), pl.cdiv(shape[1], bn))
    return grid, pl.BlockSpec((bm, bn), lambda i, j: (i, j))


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def ilm_mul_2d(a, b, *, iters: int = 16, block=DEFAULT_BLOCK, interpret: bool = True):
    grid, spec = _grid_spec(a.shape, block)
    return pl.pallas_call(
        functools.partial(_ilm_mul_kernel, iters=iters),
        grid=grid, in_specs=[spec, spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.uint32),
        interpret=interpret,
    )(a.astype(jnp.uint32), b.astype(jnp.uint32))


@functools.partial(jax.jit, static_argnames=("iters", "block", "interpret"))
def ilm_square_2d(a, *, iters: int = 16, block=DEFAULT_BLOCK, interpret: bool = True):
    grid, spec = _grid_spec(a.shape, block)
    return pl.pallas_call(
        functools.partial(_ilm_square_kernel, iters=iters),
        grid=grid, in_specs=[spec], out_specs=spec,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.uint32),
        interpret=interpret,
    )(a.astype(jnp.uint32))
