"""Shared kernel-body math: traceable inside Pallas kernels and in ref oracles.

Everything here is straight-line jnp on values already resident in VMEM —
no gathers (the PWL "ROM" is a compare/select ladder over compile-time
constants, which vectorizes perfectly on the VPU), no data-dependent shapes.
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.core.seeds import SeedTable, compute_segments, rsqrt_seed_table

# One source of truth for the f32 field layout: the jnp twins' bit-level
# datapath (core/fpparts.py) and these kernel bodies must stay aligned
# field-for-field — the underflow="ftz" twins are pinned bit-identical to
# the fused kernels by tests/test_underflow_policy.py.
from repro.core.fpparts import (  # noqa: F401  (re-exported kernel-side)
    F32_SIGN, F32_MAG_MASK, F32_EXP_MASK, F32_MAN_MASK, F32_ONE_BITS,
    F32_IMPLICIT,
)


def seed_ladder(man: jax.Array, table: SeedTable) -> jax.Array:
    """PWL seed via compare/select ladder (the hardware LUT, vectorized).

    man must lie in [table.boundaries[0], table.boundaries[-1])."""
    slopes = table.slopes.astype(np.float32)
    intercepts = table.intercepts.astype(np.float32)
    y0 = slopes[0] * man + intercepts[0]
    for i, b in enumerate(table.inner_boundaries.astype(np.float32)):
        y0 = jnp.where(man >= b, slopes[i + 1] * man + intercepts[i + 1], y0)
    return y0


def series_refine(y0: jax.Array, man: jax.Array, n: int, schedule: str) -> jax.Array:
    """y0 * sum m^k with m = 1 - man*y0 (paper eq. 11), unrolled at trace time.

    The residual m is computed at full seed-product width (Dekker two-product,
    see taylor.exact_residual) and the series is accumulated without the
    leading 1 — together these keep the fused kernel within ~1 ulp of the
    exact reciprocal at the f32 operating point (n=2, 24-bit table).

    schedule="goldschmidt" runs the Goldschmidt residual-register recurrence
    (N += N*r; r *= r) instead of explicit powering — iters_for_terms(n)
    iterations cover the same series terms as the factored schedule.
    """
    from repro.core.taylor import exact_residual, series_sum

    if n <= 0:
        return y0
    if schedule == "goldschmidt":
        from repro.core.goldschmidt import _refine, iters_for_terms

        return _refine(y0, man, y0, iters_for_terms(n))
    return y0 + y0 * series_sum(jnp, exact_residual(man, y0), n, schedule)


def recip_f32_bits(x: jax.Array, table: SeedTable, n: int, schedule: str) -> jax.Array:
    """Full f32 reciprocal with explicit bit-level unpack/repack.

    This is the hardware datapath: sign/exponent/mantissa split, PWL seed on
    the mantissa in [1,2), series refinement, exponent negation by biased-
    exponent arithmetic. Denormal inputs flush to +-inf (treated as zero);
    reciprocals that would be denormal flush to +-0 — standard FTZ semantics
    of fast hardware dividers.
    """
    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = bits & F32_SIGN
    exp = (bits >> 23) & jnp.uint32(0xFF)
    man_bits = bits & F32_MAN_MASK
    man = jax.lax.bitcast_convert_type(man_bits | F32_ONE_BITS, jnp.float32)
    rman = series_refine(seed_ladder(man, table), man, n, schedule)  # (0.5, 1]
    # 2^-(exp-127) has biased exponent 254-exp; clamp into the normal range.
    scale_exp = jnp.clip(jnp.uint32(254) - exp, jnp.uint32(0), jnp.uint32(254))
    scale = jax.lax.bitcast_convert_type(scale_exp << 23, jnp.float32)
    r = rman * scale
    # Edges: zero/denormal -> inf; inf -> 0; nan -> nan.
    r = jnp.where(exp == 0, jnp.float32(np.inf), r)
    r = jnp.where((exp == 255) & (man_bits == 0), jnp.float32(0.0), r)
    rbits = jax.lax.bitcast_convert_type(r, jnp.uint32) | sign
    r = jax.lax.bitcast_convert_type(rbits, jnp.float32)
    return jnp.where((exp == 255) & (man_bits != 0), jnp.float32(np.nan), r)


def _pow2(k: jax.Array) -> jax.Array:
    """2^k for int32 k in [-126, 127], built by biased-exponent bitcast."""
    return jax.lax.bitcast_convert_type(
        (jnp.clip(k + 127, 1, 254).astype(jnp.uint32)) << 23, jnp.float32)


def divide_f32_bits(a: jax.Array, b: jax.Array, table: SeedTable, n: int,
                    schedule: str) -> jax.Array:
    """Fused exponent-separated a/b: the full divide datapath in one kernel.

    Sign xor, biased-exponent subtract, mantissa pair in [1, 2), then either
    the joint N/D Goldschmidt recurrence (schedule="goldschmidt": the
    numerator mantissa rides the F-multiplies, arXiv:1909.10154) or the
    Taylor series reciprocal with the Markstein-corrected final multiply
    (fpparts.refine_quotient — the full-width final multiplier of Fig. 7).
    The exponent difference is applied in two power-of-two multiplies so the
    intermediate scale never under/overflows while a/b is representable.
    FTZ semantics as elsewhere: denormal operands are treated as zeros and
    denormal quotients flush to +-0.
    """
    from repro.core import fpparts

    abits = jax.lax.bitcast_convert_type(a, jnp.uint32)
    bbits = jax.lax.bitcast_convert_type(b, jnp.uint32)
    sign = (abits ^ bbits) & F32_SIGN
    ea = ((abits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    eb = ((bbits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    amant = abits & F32_MAN_MASK
    bmant = bbits & F32_MAN_MASK
    man_a = jax.lax.bitcast_convert_type(amant | F32_ONE_BITS, jnp.float32)
    man_b = jax.lax.bitcast_convert_type(bmant | F32_ONE_BITS, jnp.float32)
    y0 = seed_ladder(man_b, table)
    if schedule == "goldschmidt":
        from repro.core.goldschmidt import _refine, iters_for_terms

        q_man = _refine(man_a * y0, man_b, y0, iters_for_terms(n))
    else:
        rman = series_refine(y0, man_b, n, schedule)
        q_man = fpparts.refine_quotient(man_a * rman, man_a, man_b, rman)
    # q = q_man * 2^(ea-eb), split so each factor is a normal power of two.
    de = ea - eb                                    # biased diff == unbiased diff
    h = de >> 1
    q = (q_man * _pow2(h)) * _pow2(de - h)
    # FTZ: quotients below the normal range flush to zero (sign added below).
    q = jnp.where(jnp.abs(q) < jnp.float32(2.0 ** -126), jnp.float32(0.0), q)
    # Edge classes on the FTZ'd operands: exp 0 => zero, exp 255 => inf/nan.
    a_zero, b_zero = ea == 0, eb == 0
    a_inf = (ea == 255) & (amant == 0)
    b_inf = (eb == 255) & (bmant == 0)
    q = jnp.where(b_zero, jnp.float32(np.inf), q)            # x/0 -> inf
    q = jnp.where(a_zero, jnp.float32(0.0), q)               # 0/x -> 0
    q = jnp.where(a_inf, jnp.float32(np.inf), q)             # inf/x -> inf
    q = jnp.where(b_inf, jnp.float32(0.0), q)                # x/inf -> 0
    q = jnp.where(a_zero & b_zero, jnp.float32(np.nan), q)   # 0/0
    q = jnp.where(a_inf & b_inf, jnp.float32(np.nan), q)     # inf/inf
    qbits = jax.lax.bitcast_convert_type(q, jnp.uint32) | sign
    q = jax.lax.bitcast_convert_type(qbits, jnp.float32)
    a_nan = (ea == 255) & (amant != 0)
    b_nan = (eb == 255) & (bmant != 0)
    return jnp.where(a_nan | b_nan, jnp.float32(np.nan), q)


def rsqrt_f32_bits(x: jax.Array, table: SeedTable, newton_iters: int) -> jax.Array:
    """Full f32 rsqrt with explicit bit-level unpack and the IEEE edge
    contract — the fused-kernel twin of ``core.taylor._rsqrt_bits``.

    Same datapath as :func:`rsqrt_f32` (even/odd exponent split onto one
    seed octave, PWL chord seed, Newton with the residual-compensated final
    step) but classification is bit tests and every edge class is handled:
    FTZ semantics as everywhere in the kernels — a zero exponent field
    (zero or subnormal) is the zero class -> signed inf; +inf -> +0;
    negative operands (including -inf) and nans -> nan. Bit-identical to
    the jnp twin under ``underflow="ftz"`` (the seed ladder selects the
    same segment the jnp ``take`` does, and the Newton arithmetic is
    shared).
    """
    from repro.core.taylor import _newton_rsqrt

    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    sign = bits & F32_SIGN
    mag = bits & F32_MAG_MASK
    exp = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32)
    man_bits = bits & F32_MAN_MASK
    x_zero = exp == 0                       # FTZ: zero/subnormal class
    x_inf = mag == F32_EXP_MASK
    x_nan = mag > F32_EXP_MASK
    man = jax.lax.bitcast_convert_type(man_bits | F32_ONE_BITS, jnp.float32)
    ef = exp - 127 + 1                      # frexp convention: |x| = (man/2)*2^ef
    s = ef >> 1                             # floor(ef / 2)
    odd = ef - 2 * s                        # 0 or 1
    u = jnp.where(odd == 1, man, man * jnp.float32(0.5))   # in [0.5, 2)
    y = _newton_rsqrt(u, seed_ladder(u, table), newton_iters)
    pw = jax.lax.bitcast_convert_type(
        jnp.clip(127 - s, 1, 254).astype(jnp.uint32) << 23, jnp.float32)
    r = y * pw                              # exact: rsqrt results are normal
    inf_s = jax.lax.bitcast_convert_type(F32_EXP_MASK | sign, jnp.float32)
    r = jnp.where(x_zero, inf_s, r)                      # +-0/sub -> +-inf
    r = jnp.where(x_inf, jnp.float32(0.0), r)            # +inf -> +0
    neg = (sign != 0) & ~x_zero                          # x < 0 -> nan
    return jnp.where(neg | x_nan, jnp.float32(np.nan), r)


def rsqrt_f32(x: jax.Array, table: SeedTable, newton_iters: int) -> jax.Array:
    """rsqrt for strictly-positive x (norm denominators): PWL seed + Newton.

    The final Newton step is residual-compensated (core.taylor._newton_rsqrt
    — two Dekker two-products) so the fused norms deliver the same ~0.5 ULP
    the jnp rsqrt twin does, instead of the ~2 ULP plain steps leave.
    """
    from repro.core.taylor import _newton_rsqrt

    bits = jax.lax.bitcast_convert_type(x, jnp.uint32)
    exp = ((bits >> 23) & jnp.uint32(0xFF)).astype(jnp.int32) - 127
    man = jax.lax.bitcast_convert_type(
        (bits & F32_MAN_MASK) | F32_ONE_BITS, jnp.float32)
    # x = man * 2^exp; with s = floor(exp/2): u = man * 2^(exp-2s) in [1, 4) —
    # shift the seed domain [0.5, 2) by scaling u by 1/2 and result by sqrt(2).
    s = exp >> 1  # floor division (arithmetic shift)
    odd = exp - 2 * s  # 0 or 1
    u = jnp.where(odd == 1, man * 2.0, man) * 0.5  # in [0.5, 2)
    y = _newton_rsqrt(u, seed_ladder(u, table), newton_iters)
    # rsqrt(x) = rsqrt(2u * 2^(2s + odd - 1)) ... assembled as y * 2^-(s)/sqrt(2)*...
    # We defined u = man' / 2 with man' in [1,4), x = man' * 2^(2s).
    # rsqrt(x) = rsqrt(2u) * 2^-s = y / sqrt(2) * 2^-s.
    inv_sqrt2 = jnp.float32(1.0 / np.sqrt(2.0))
    pow2 = jax.lax.bitcast_convert_type(
        ((jnp.clip(127 - s, 1, 254)).astype(jnp.uint32)) << 23, jnp.float32)
    return y * inv_sqrt2 * pow2
