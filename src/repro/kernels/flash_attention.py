"""Pallas TPU kernel: flash attention with Taylor-series-division softmax.

Online-softmax attention (Dao et al.) adapted to the paper's division unit:
the running row statistics (m, l) accumulate across key blocks; the final
1/l normalization is the paper's PWL-seed + Taylor-refinement reciprocal
(recip_f32_bits) instead of a hardware divide. Score tiles live in VMEM for
their whole lifetime — HBM sees only Q/K/V reads and one output write, which
is what zeroes the score term of the memory roofline (launch/memmodel.py,
fused_attention=True).

Grid: (batch*heads, q_blocks, k_blocks); k_blocks is the sequential
('arbitrary') dimension — m/l/acc carriers are revisited outputs indexed by
(bh, qi) only. Block shapes default to (128, head_dim) q x (128, head_dim) k:
with hd=128 that is 64 KiB q + 64 KiB k/v + 64 KiB score tile in f32 —
comfortably double-bufferable in VMEM, MXU-aligned (128x128 tiles).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.core.seeds import compute_segments
from . import common

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_k_blocks: int, sk_real: int, table, n_iters: int,
                  schedule: str, skip_masked_k: bool):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    def _accumulate():
        q = q_ref[0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        if sk_real < n_k_blocks * block_k:
            # Ragged key length: positions past sk_real are wrapper padding,
            # masked out of every row's statistics (exp(NEG_INF - m) = 0).
            kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(kpos < sk_real, s, NEG_INF)

        m_prev = m_ref[0]                              # (bq, 1)
        l_prev = l_ref[0]
        m_cur = jnp.max(s, axis=-1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        corr = jnp.exp(m_prev - m_new)                 # (bq, 1)
        l_new = corr * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc_ref[0] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)

        m_ref[0] = m_new
        l_ref[0] = l_new
        acc_ref[0] = acc

    if causal and skip_masked_k:
        # Early skip for fully-masked key blocks (the whole block sits
        # strictly above the diagonal: ki*block_k > (qi+1)*block_q - 1).
        # Bit-identical to running them — a skipped block's contribution is
        # exactly p = exp(NEG_INF - m_prev) = 0 with m/l/acc unchanged —
        # but saves the QK^T matmul and the exp/rescale arithmetic. The
        # finalize moves to the last *contributing* block.
        pl.when(ki * block_k <= (qi + 1) * block_q - 1)(_accumulate)
        last_k = jnp.minimum(jnp.int32(n_k_blocks - 1),
                             ((qi + 1) * block_q - 1) // block_k)
    else:
        _accumulate()
        last_k = n_k_blocks - 1

    @pl.when(ki == last_k)
    def _finalize():
        # the paper's division unit: 1/l via PWL seed + Taylor refinement
        # (schedule="goldschmidt" runs the joint residual recurrence)
        rl = common.recip_f32_bits(l_ref[0], table, n_iters, schedule)
        o_ref[0] = (acc_ref[0] * rl).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "n_iters",
                     "precision_bits", "schedule", "sk_real",
                     "skip_masked_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    n_iters: int = 2, precision_bits: int = 24,
                    schedule: str = "factored", sk_real: int | None = None,
                    skip_masked_k: bool = True, interpret: bool = True):
    """q/k/v: (BH, S, hd) -> (BH, S, hd). Causal flash attention, tsdiv softmax.

    Block-multiple shapes only — ``kernels.ops.flash_attention`` pads ragged
    sequences and passes ``sk_real`` so padded key positions are masked
    in-kernel. ``skip_masked_k=False`` disables the above-diagonal
    early-skip (kept as a knob so the bit-identity of the skip is testable).
    """
    bh, sq, hd = q.shape
    sk = k.shape[1]
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0
    nq, nk = sq // block_q, sk // block_k
    table = compute_segments(n_iters, precision_bits)
    scale = 1.0 / math.sqrt(hd)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, n_k_blocks=nk, sk_real=sk if sk_real is None else sk_real,
        table=table, n_iters=n_iters, schedule=schedule,
        skip_masked_k=skip_masked_k)

    out, _, _, _ = pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            # per-(b, q-block) carriers: race-free when b/i run in parallel;
            # on TPU these become VMEM scratch via scratch_shapes instead
            pl.BlockSpec((1, block_q, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, sq, hd), q.dtype),
            jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),    # m carrier
            jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32),    # l carrier
            jax.ShapeDtypeStruct((bh, sq, hd), jnp.float32),   # acc carrier
        ],
        interpret=interpret,
    )(q, k, v)
    return out
