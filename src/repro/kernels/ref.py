"""Pure-jnp oracles for every Pallas kernel (no pallas imports).

Two tiers per kernel:
  * ``*_ref``   — same algorithm, pure jnp (bit-comparable with the kernel);
  * ``*_exact`` — the mathematically exact op (what eq. 17 bounds against).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import ilm as ilm_core
from repro.core.seeds import compute_segments, rsqrt_seed_table
from . import common


def tsdiv_recip_ref(x, *, n_iters: int = 2, precision_bits: int = 24,
                    schedule: str = "factored"):
    table = compute_segments(n_iters, precision_bits)
    return common.recip_f32_bits(x.astype(jnp.float32), table, n_iters, schedule)


def tsdiv_recip_exact(x):
    return 1.0 / x.astype(jnp.float32)


def tsdiv_divide_ref(a, b, *, n_iters: int = 2, precision_bits: int = 24,
                     schedule: str = "factored"):
    table = compute_segments(n_iters, precision_bits)
    return common.divide_f32_bits(a.astype(jnp.float32), b.astype(jnp.float32),
                                  table, n_iters, schedule)


def tsdiv_divide_exact(a, b):
    return a.astype(jnp.float32) / b.astype(jnp.float32)


def rmsnorm_ref(x, w, *, eps: float = 1e-6, newton_iters: int = 2,
                n_segments: int = 16, d_real: int | None = None):
    xf = x.astype(jnp.float32)
    d = xf.shape[-1] if d_real is None else d_real
    ss = jnp.sum(xf * xf, axis=-1, keepdims=True) / d
    se = ss + jnp.float32(eps)
    r = common.rsqrt_f32(se, rsqrt_seed_table(n_segments), newton_iters)
    # same row edge classes as the kernel: nan propagates, inf scales by 0
    r = jnp.where(jnp.isinf(se), jnp.float32(0.0), r)
    r = jnp.where(jnp.isnan(se), jnp.float32(jnp.nan), r)
    return (xf * r * w.astype(jnp.float32)).astype(x.dtype)


def rmsnorm_exact(x, w, *, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    ss = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ss + eps) * w.astype(jnp.float32)).astype(x.dtype)


def softmax_ref(x, *, n_iters: int = 2, precision_bits: int = 24,
                schedule: str = "factored"):
    xf = x.astype(jnp.float32)
    xmax = jnp.max(xf, axis=-1, keepdims=True)
    ex = jnp.exp(xf - xmax)
    s = jnp.sum(ex, axis=-1, keepdims=True)
    table = compute_segments(n_iters, precision_bits)
    return (ex * common.recip_f32_bits(s, table, n_iters, schedule)).astype(x.dtype)


def softmax_exact(x):
    return jax.nn.softmax(x.astype(jnp.float32), axis=-1).astype(x.dtype)


def flash_attention_exact(q, k, v, *, causal: bool = True):
    """Plain softmax attention oracle. q/k/v: (BH, S, hd)."""
    import math

    hd = q.shape[-1]
    s = jnp.einsum("bqh,bkh->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkh->bqh", p, v.astype(jnp.float32)).astype(q.dtype)


def ilm_mul_ref(a, b, *, iters: int = 16):
    return ilm_core.ilm_mul(a, b, iters)


def ilm_mul_exact(a, b):
    return (a.astype(jnp.uint32) * b.astype(jnp.uint32))


def ilm_square_ref(a, *, iters: int = 16):
    return ilm_core.ilm_square(a, iters)


def ilm_square_exact(a):
    a = a.astype(jnp.uint32)
    return a * a
