"""Pallas TPU kernels for the paper's division unit and its fusion sites.

Layout per kernel: <name>.py (pl.pallas_call + BlockSpec), ref.py (pure-jnp
oracles), ops.py (shape-generic jit wrappers). CPU validates via interpret
mode; TPU is the compilation target.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
