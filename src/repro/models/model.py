"""Model assembly: pattern-driven layer stacks lowered as per-group lax.scans.

A config's layer pattern (mixer x ffn per layer) is grouped into periodic
blocks (configs.base.Group); each group lowers as ONE lax.scan over its
``repeat`` dim with parameters stacked on a leading 'layers' axis. HLO size is
O(period), not O(depth) — Jamba's 72 layers compile as a 9-iteration scan over
an 8-layer body. Caches stack the same way and ride the scan as xs/ys.

Modes: 'train' (no cache), 'prefill' (emit cache), 'decode' (carry cache).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import Group, LayerSpec, ModelConfig
from .attention import (abstract_cache_attn, decode_attention, full_attention,
                        init_cache_attn, sliding_attention)
from .layers import embed_tokens, gated_mlp, lm_logits, rms_norm
from .mamba2 import (abstract_cache_mamba, decode_mamba, init_cache_mamba,
                     mamba_mixer)
from .moe import moe_ffn


# ----------------------------------------------------------------- kv capture

def _kv_for_cache(p, x, positions, cfg: ModelConfig):
    """Recompute post-rope K/V for prefill cache. XLA CSEs these einsums with
    the ones inside the attention call (identical operands)."""
    from .attention import rope_apply

    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    k = rope_apply(k, positions, cfg)
    return k, v


def _ring_from_prefill(k, window: int, lengths=None):
    """Arrange the last `window` entries into ring order slot = pos % window.

    With per-request ``lengths`` (padded-prompt serving), each row's ring
    holds its last ``window`` REAL positions (lengths[i]-window .. lengths[i]-1)
    so right-pad tokens never enter the sliding-window cache; positions < 0
    (prompt shorter than the window) leave zero slots that decode's validity
    mask excludes.
    """
    b, s = k.shape[0], k.shape[1]
    if lengths is None:
        if s <= window:
            pad = [(0, 0)] * k.ndim
            pad[1] = (0, window - s)
            return jnp.pad(k, pad)
        last = k[:, -window:]
        slots = jnp.mod(jnp.arange(s - window, s), window)
        ring = jnp.zeros((b, window, *k.shape[2:]), k.dtype)
        return ring.at[:, slots].set(last)
    pos = lengths[:, None] - window + jnp.arange(window)[None, :]     # (b, W)
    ok = (pos >= 0)[:, :, None, None]
    gathered = jnp.take_along_axis(k, jnp.maximum(pos, 0)[:, :, None, None],
                                   axis=1)
    gathered = jnp.where(ok, gathered, 0)
    # pos covers window consecutive ints per row, so mod is a bijection onto
    # slots — invalid (negative) entries land on slots no valid entry claims.
    slot = jnp.mod(pos, window)
    ring = jnp.zeros((b, window, *k.shape[2:]), k.dtype)
    return ring.at[jnp.arange(b)[:, None], slot].set(gathered)


# --------------------------------------------------------------- block fwd

def block_forward(bp: Dict, x, spec: LayerSpec, cfg: ModelConfig, positions,
                  *, mode: str, cache=None, pos=None, enc_out=None,
                  lengths=None):
    """Returns (x, new_cache, aux). ``lengths`` (prefill only): per-request
    real prompt lengths of a right-padded batch — pad positions become SSM
    no-ops and are excluded from sliding-window rings."""
    div = cfg.division
    aux = jnp.float32(0.0)
    new_cache: Dict[str, Any] = {}

    h = rms_norm(x, bp["mixer_norm"], div, cfg.norm_eps)
    if spec.mixer == "mamba":
        if mode == "decode":
            mh, new_cache["mamba"] = decode_mamba(bp["mamba"], h, cache["mamba"], cfg)
        elif mode == "prefill":
            mh, new_cache["mamba"] = mamba_mixer(bp["mamba"], h, cfg,
                                                 return_state=True,
                                                 lengths=lengths)
        else:
            mh = mamba_mixer(bp["mamba"], h, cfg)
        x = x + mh
    else:
        window = cfg.sliding_window if spec.mixer == "swa" else 0
        if mode == "decode":
            ah, new_cache["attn"] = decode_attention(
                bp["attn"], h, cache["attn"], pos, cfg, window=window)
        else:
            fn = sliding_attention if spec.mixer == "swa" else full_attention
            ah = fn(bp["attn"], h, positions, cfg)
            if mode == "prefill":
                k, v = _kv_for_cache(bp["attn"], h, positions, cfg)
                if window:
                    k = _ring_from_prefill(k, window, lengths)
                    v = _ring_from_prefill(v, window, lengths)
                new_cache["attn"] = {"k": k.astype(cfg.param_dtype),
                                     "v": v.astype(cfg.param_dtype)}
        x = x + ah

    if "cross" in bp:  # encoder-decoder cross attention
        hc = rms_norm(x, bp["cross_norm"], div, cfg.norm_eps)
        if mode == "decode":
            ck, cv = cache["cross"]["ck"], cache["cross"]["cv"]
            ch, _ = decode_attention(bp["cross"], hc, None, pos, cfg,
                                     kv_override=(ck, cv))
            new_cache["cross"] = cache["cross"]
        else:
            ck = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross"]["wk"])
            cv = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross"]["wv"])
            ch = full_attention(bp["cross"], hc, positions, cfg, causal=False,
                                kv_override=(ck, cv))
            if mode == "prefill":
                new_cache["cross"] = {"ck": ck, "cv": cv}
        x = x + ch

    if spec.ffn != "none":
        h2 = rms_norm(x, bp["ffn_norm"], div, cfg.norm_eps)
        if spec.ffn == "moe":
            ff, a = moe_ffn(bp["ffn"], h2, cfg)
            aux = aux + a
        else:
            ff = gated_mlp(bp["ffn"], h2)
        x = x + ff
    return x, new_cache, aux


# --------------------------------------------------------------- group scan

def _group_forward(gparams, group: Group, x, cfg: ModelConfig, positions, *,
                   mode: str, gcache=None, pos=None, enc_out=None,
                   specs_override=None, lengths=None):
    specs = specs_override or group.period

    def body_fn(carry, scanned):
        xc, auxc = carry
        if mode == "decode":
            lp, lc = scanned
        else:
            lp, lc = scanned, None
        new_caches = []
        seq_shard = cfg.sharding_rules.get("__seq_shard__")
        for i, spec in enumerate(specs):
            cache_i = lc["layers"][i] if lc is not None else None
            xc, nc, a = block_forward(lp["layers"][i], xc, spec, cfg, positions,
                                      mode=mode, cache=cache_i, pos=pos,
                                      enc_out=enc_out, lengths=lengths)
            if seq_shard is not None:
                # Megatron-SP: keep the residual stream sequence-sharded over
                # the model axis between blocks; GSPMD turns the TP all-reduce
                # pairs into reduce-scatter + all-gather (half the wire bytes).
                from repro.sharding.rules import shard_dim
                xc = shard_dim(xc, 1, seq_shard)
            new_caches.append(nc)
            auxc = auxc + a
        ys = {"layers": new_caches} if mode in ("prefill", "decode") else None
        return (xc, auxc), ys

    if cfg.remat and mode == "train":
        body_fn = jax.checkpoint(body_fn)

    carry0 = (x, jnp.float32(0.0))
    if group.repeat == 1:
        sc = (gparams, gcache) if mode == "decode" else gparams
        (x, aux), ys = body_fn(carry0, sc)
        return x, ys, aux
    xs = (gparams, gcache) if mode == "decode" else gparams
    unroll = group.repeat if cfg.scan_unroll else 1
    (x, aux), ys = jax.lax.scan(body_fn, carry0, xs, unroll=unroll)
    return x, ys, aux


# ----------------------------------------------------------------- encoder

def encode(cfg: ModelConfig, enc_params, enc_embeds):
    """Non-causal full-attention encoder over stub frontend embeddings."""
    b, s, _ = enc_embeds.shape
    positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = enc_embeds.astype(cfg.param_dtype)
    spec = LayerSpec("attn", "dense")

    def body_fn(carry, lp):
        xc, _ = carry
        xc, _, _ = block_forward(lp["layers"][0], xc, spec, cfg, positions,
                                 mode="train")
        return (xc, jnp.float32(0.0)), None

    if cfg.n_encoder_layers == 1:
        (x, _), _ = body_fn((x, jnp.float32(0.0)), enc_params["groups"][0])
    else:
        (x, _), _ = jax.lax.scan(
            body_fn, (x, jnp.float32(0.0)), enc_params["groups"][0],
            unroll=cfg.n_encoder_layers if cfg.scan_unroll else 1)
    return rms_norm(x, enc_params["final_norm"], cfg.division, cfg.norm_eps)


# ------------------------------------------------------------------ forward

def forward(cfg: ModelConfig, params, *, tokens=None, embeds=None, cache=None,
            pos=None, mode: str = "train", enc_embeds=None, lengths=None):
    """Returns (logits, new_cache, aux). ``cache``/``pos`` for decode;
    ``enc_embeds`` for enc-dec / stub-frontend archs.

    ``pos`` (decode) may be a scalar or a per-request (b,) vector — the
    serving engine's padded-prompt fix decodes each request at its own
    absolute position. ``lengths`` (prefill) marks per-request real prompt
    lengths of a right-padded batch: pad positions become SSM no-ops and are
    excluded from sliding-window ring caches.
    """
    enc_out = None
    if cfg.is_encoder_decoder and mode != "decode":
        enc_out = encode(cfg, params["encoder"], enc_embeds)

    if embeds is not None and cfg.embed_inputs and not cfg.is_encoder_decoder:
        x = embeds.astype(cfg.param_dtype)
        b, s = x.shape[0], x.shape[1]
    else:
        x = embed_tokens(params["embed"], tokens, cfg)
        b, s = tokens.shape

    if mode == "decode":
        from .attention import decode_positions
        pos = decode_positions(pos, b)
        positions = pos[:, None]
        lengths = None
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        if lengths is not None:
            lengths = jnp.asarray(lengths, jnp.int32)

    aux_total = jnp.float32(0.0)
    new_groups: List[Any] = []
    for gi, group in enumerate(cfg.groups()):
        gparams = params["groups"][gi]
        gcache = cache["groups"][gi] if cache is not None else None
        x, gc, aux = _group_forward(gparams, group, x, cfg, positions,
                                    mode=mode, gcache=gcache, pos=pos,
                                    enc_out=enc_out, lengths=lengths)
        new_groups.append(gc)
        aux_total = aux_total + aux

    x = rms_norm(x, params["final_norm"], cfg.division, cfg.norm_eps)
    logits = lm_logits(params, x, cfg)
    new_cache = {"groups": new_groups} if mode in ("prefill", "decode") else None
    return logits, new_cache, aux_total


# -------------------------------------------------------------------- caches

def _block_cache(cfg: ModelConfig, spec: LayerSpec, batch: int, max_len: int,
                 abstract: bool, cross: bool):
    dtype = jnp.dtype(cfg.param_dtype)
    mk_attn = abstract_cache_attn if abstract else init_cache_attn
    mk_mamba = abstract_cache_mamba if abstract else init_cache_mamba
    out: Dict[str, Any] = {}
    if spec.mixer == "mamba":
        out["mamba"] = mk_mamba(cfg, batch, dtype)
    else:
        window = cfg.sliding_window if spec.mixer == "swa" else 0
        out["attn"] = mk_attn(cfg, batch, max_len, window, dtype)
    if cross:
        shape = (batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.head_dim)
        if abstract:
            out["cross"] = {"ck": jax.ShapeDtypeStruct(shape, dtype),
                            "cv": jax.ShapeDtypeStruct(shape, dtype)}
        else:
            out["cross"] = {"ck": jnp.zeros(shape, dtype),
                            "cv": jnp.zeros(shape, dtype)}
    return out


def make_cache(cfg: ModelConfig, batch: int, max_len: int, abstract: bool = False):
    """Decode cache matching the grouped/stacked parameter layout."""
    groups = []
    for g in cfg.groups():
        layer_caches = [
            _block_cache(cfg, s, batch, max_len, abstract,
                         cross=cfg.is_encoder_decoder)
            for s in g.period
        ]
        tree = {"layers": layer_caches}
        if g.repeat > 1:
            if abstract:
                tree = jax.tree_util.tree_map(
                    lambda a: jax.ShapeDtypeStruct((g.repeat, *a.shape), a.dtype),
                    tree)
            else:
                tree = jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(a, (g.repeat, *a.shape)).copy(), tree)
        groups.append(tree)
    return {"groups": groups}
