"""Mamba-2 (SSD, state-space duality) mixer: chunked train/prefill + recurrent decode.

Faithful minimal SSD (arXiv:2405.21060 listing 1 semantics):
  state:  S_t = exp(dt_t * A) S_{t-1} + dt_t * B_t x_t^T      (per head)
  output: y_t = C_t . S_t + D * x_t
Chunked form: intra-chunk attention-like term via the decay matrix
L[i,j] = exp(a_i - a_j) (i >= j, a = within-chunk cumsum of dt*A), plus the
inter-chunk carried state propagated by a lax.scan over chunks.

The projections are split (wz/wx/wB/wC/wdt + per-part depthwise convs) so the
'ssm_inner' dim shards cleanly over the model axis without slicing a fused
projection at unaligned offsets.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import division_modes as dm


def _causal_conv(u, w, width: int):
    """Depthwise causal conv via explicit shifts. u: (b, l, c); w: (width, c)."""
    out = u * w[-1]
    for k in range(1, width):
        shifted = jnp.pad(u, ((0, 0), (k, 0), (0, 0)))[:, : u.shape[1]]
        out = out + shifted * w[-1 - k]
    return out


def _segsum_decay(a):
    """L[i,j] = exp(cumsum_i - cumsum_j) masked to i >= j. a: (..., q)."""
    q = a.shape[-1]
    ac = jnp.cumsum(a, axis=-1)
    diff = ac[..., :, None] - ac[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def mamba_mixer(p: Dict, x, cfg: ModelConfig, *, initial_state=None,
                return_state: bool = False, lengths=None):
    """x: (b, l, d_model) -> (b, l, d_model). Chunked SSD over cfg.ssm_chunk.

    ``lengths`` (per-request real lengths of a right-padded batch) zeroes dt
    at pad positions, so decay = exp(0*A) = 1 and input contribution dt*B*x = 0
    there: the returned state equals the state after each row's real tokens.
    """
    b, l, _ = x.shape
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = min(cfg.ssm_chunk, l)
    assert l % q == 0, f"seq {l} not divisible by chunk {q}"
    nc = l // q

    z = jnp.einsum("bld,di->bli", x, p["wz"])
    xs_raw = jnp.einsum("bld,di->bli", x, p["wx"])
    B_raw = jnp.einsum("bld,dn->bln", x, p["wB"])
    C_raw = jnp.einsum("bld,dn->bln", x, p["wC"])
    dt_raw = jnp.einsum("bld,dh->blh", x, p["wdt"]).astype(jnp.float32)

    xs = _causal_conv(xs_raw, p["conv_x"], cfg.conv_width)
    Bc = _causal_conv(B_raw, p["conv_B"], cfg.conv_width).astype(jnp.float32)
    Cc = _causal_conv(C_raw, p["conv_C"], cfg.conv_width).astype(jnp.float32)
    xs = jax.nn.silu(xs.astype(jnp.float32))
    Bc = jax.nn.silu(Bc)
    Cc = jax.nn.silu(Cc)

    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(jnp.float32))  # (b,l,h)
    if lengths is not None:
        tmask = jnp.arange(l)[None, :] < lengths[:, None]            # (b,l)
        dt = dt * tmask[:, :, None]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                     # (h,)
    xh = xs.reshape(b, l, h, pdim)                                   # heads split

    # chunk views
    xc = xh.reshape(b, nc, q, h, pdim)
    dtc = dt.reshape(b, nc, q, h)
    Bq = Bc.reshape(b, nc, q, n)
    Cq = Cc.reshape(b, nc, q, n)
    adt = dtc * A  # (b,nc,q,h)

    # ---- intra-chunk (diagonal blocks)
    Ldec = _segsum_decay(jnp.moveaxis(adt, -1, -2))          # (b,nc,h,q,q)
    scores = jnp.einsum("bcin,bcjn->bcij", Cq, Bq)           # (b,nc,q,q)
    w = scores[:, :, None, :, :] * Ldec                      # (b,nc,h,i,j)
    xw = xc * dtc[..., None]                                 # dt_j x_j
    y_intra = jnp.einsum("bchij,bcjhp->bcihp", w, xw)

    # ---- per-chunk end states: S_c = sum_j exp(a_end - a_j) dt_j B_j x_j^T
    acum = jnp.cumsum(adt, axis=2)                           # (b,nc,q,h)
    decay_to_end = jnp.exp(acum[:, :, -1:, :] - acum)        # (b,nc,q,h)
    Sc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", decay_to_end * dtc, Bq, xc)

    # ---- inter-chunk scan
    chunk_decay = jnp.exp(acum[:, :, -1, :])                 # (b,nc,h)
    S0 = (jnp.zeros((b, h, pdim, n), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def body(S_prev, xs_c):
        S_c, dec_c = xs_c                                    # (b,h,p,n), (b,h)
        S_new = S_c + dec_c[..., None, None] * S_prev
        return S_new, S_prev

    S_last, S_prevs = jax.lax.scan(
        body, S0, (jnp.moveaxis(Sc, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
        unroll=nc if cfg.scan_unroll else 1)
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                    # (b,nc,h,p,n)

    y_inter = jnp.einsum("bcin,bcih,bchpn->bcihp",
                         Cq, jnp.exp(acum), S_prevs)
    y = (y_intra + y_inter).reshape(b, l, h, pdim)
    y = y + p["D"].astype(jnp.float32)[None, None, :, None] * xh
    y = y.reshape(b, l, h * pdim)

    # gated RMSNorm then output projection
    from .layers import rms_norm
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.division, cfg.norm_eps)
    out = jnp.einsum("bli,id->bld", y, p["wout"])
    if return_state:
        wm1 = cfg.conv_width - 1

        def tail(u):
            if lengths is None:
                return u[:, -wm1:]
            # last wm1 REAL positions per row; pos < 0 (prompt shorter than
            # the conv window) matches the zero-initialized decode conv state.
            tpos = lengths[:, None] - wm1 + jnp.arange(wm1)[None, :]
            ok = (tpos >= 0)[:, :, None]
            g = jnp.take_along_axis(u, jnp.maximum(tpos, 0)[:, :, None], axis=1)
            return jnp.where(ok, g, 0).astype(u.dtype)

        new_cache = {
            "state": S_last,
            "conv_x": tail(xs_raw).astype(jnp.float32).astype(xs_raw.dtype),
            "conv_B": tail(B_raw),
            "conv_C": tail(C_raw),
        }
        return out, new_cache
    return out


# --------------------------------------------------------------------- cache

def init_cache_mamba(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    wm1 = cfg.conv_width - 1
    return {
        "state": jnp.zeros((batch, h, pdim, n), jnp.float32),
        "conv_x": jnp.zeros((batch, wm1, cfg.d_inner), dtype),
        "conv_B": jnp.zeros((batch, wm1, n), dtype),
        "conv_C": jnp.zeros((batch, wm1, n), dtype),
    }


def abstract_cache_mamba(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype),
        init_cache_mamba(cfg, batch, dtype))


def _conv_step(u_new, conv_state, w):
    """One-token causal conv. u_new: (b,1,c); conv_state: (b, width-1, c)."""
    window = jnp.concatenate([conv_state, u_new], axis=1)  # (b, width, c)
    out = jnp.einsum("bwc,wc->bc", window, w)[:, None, :]
    return out, window[:, 1:]


def decode_mamba(p: Dict, x, cache, cfg: ModelConfig):
    """One-token recurrent step. x: (b, 1, d_model)."""
    b = x.shape[0]
    h, pdim, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state

    z = jnp.einsum("bld,di->bli", x, p["wz"])
    xs = jnp.einsum("bld,di->bli", x, p["wx"])
    Bc = jnp.einsum("bld,dn->bln", x, p["wB"])
    Cc = jnp.einsum("bld,dn->bln", x, p["wC"])
    dt_raw = jnp.einsum("bld,dh->blh", x, p["wdt"]).astype(jnp.float32)

    xs, cx = _conv_step(xs, cache["conv_x"], p["conv_x"])
    Bc, cB = _conv_step(Bc, cache["conv_B"], p["conv_B"])
    Cc, cC = _conv_step(Cc, cache["conv_C"], p["conv_C"])
    xs = jax.nn.silu(xs.astype(jnp.float32))
    Bc = jax.nn.silu(Bc.astype(jnp.float32))[:, 0]          # (b,n)
    Cc = jax.nn.silu(Cc.astype(jnp.float32))[:, 0]
    dt = jax.nn.softplus(dt_raw + p["dt_bias"].astype(jnp.float32))[:, 0]  # (b,h)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xh = xs.reshape(b, h, pdim)
    S = cache["state"]
    decay = jnp.exp(dt * A)                                  # (b,h)
    S_new = (decay[..., None, None] * S
             + jnp.einsum("bh,bn,bhp->bhpn", dt, Bc, xh))
    y = jnp.einsum("bn,bhpn->bhp", Cc, S_new)
    y = y + p["D"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(b, 1, h * pdim)

    from .layers import rms_norm
    y = y * jax.nn.silu(z.astype(jnp.float32))
    y = rms_norm(y.astype(x.dtype), p["norm"], cfg.division, cfg.norm_eps)
    out = jnp.einsum("bli,id->bld", y, p["wout"])
    new_cache = {"state": S_new, "conv_x": cx, "conv_B": cB, "conv_C": cC}
    return out, new_cache
