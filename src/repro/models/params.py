"""Parameter specs, abstract/concrete init, and logical sharding axes.

Models are defined against plain dict pytrees. Each leaf starts life as a
``ParamSpec`` carrying shape, logical axes and init; the spec tree is
materialized either concretely (``init_params``) or abstractly
(``abstract_params`` — ShapeDtypeStructs only, so 398B-parameter configs cost
nothing). Logical axes map to mesh axes through the per-arch rules
(``sharding.rules``).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import Group, LayerSpec, ModelConfig


@dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"          # normal | zeros | ones
    scale: Optional[float] = None  # stddev for normal; default 1/sqrt(fan_in)
    dtype: Optional[str] = None    # overrides cfg.param_dtype

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


# ------------------------------------------------------------- module specs

def _attn_specs(cfg: ModelConfig, cross: bool = False) -> Dict[str, ParamSpec]:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    s_in = 1.0 / np.sqrt(d)
    s_out = 1.0 / np.sqrt(H * hd)
    return {
        "wq": ParamSpec((d, H, hd), ("embed", "heads", "head_dim"), scale=s_in),
        "wk": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), scale=s_in),
        "wv": ParamSpec((d, KV, hd), ("embed", "kv_heads", "head_dim"), scale=s_in),
        "wo": ParamSpec((H, hd, d), ("heads", "head_dim", "embed"), scale=s_out),
    }


def _mlp_specs(cfg: ModelConfig, d_ff: int) -> Dict[str, ParamSpec]:
    d = cfg.d_model
    return {
        "wi": ParamSpec((d, d_ff), ("embed", "mlp"), scale=1.0 / np.sqrt(d)),
        "wg": ParamSpec((d, d_ff), ("embed", "mlp"), scale=1.0 / np.sqrt(d)),
        "wo": ParamSpec((d_ff, d), ("mlp", "embed"), scale=1.0 / np.sqrt(d_ff)),
    }


def _moe_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    out: Dict[str, Any] = {
        "router": ParamSpec((d, E), ("embed", None), scale=1.0 / np.sqrt(d),
                            dtype="float32"),
        "wi": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"),
                        scale=1.0 / np.sqrt(d)),
        "wg": ParamSpec((E, d, f), ("experts", "embed", "expert_mlp"),
                        scale=1.0 / np.sqrt(d)),
        "wo": ParamSpec((E, f, d), ("experts", "expert_mlp", "embed"),
                        scale=1.0 / np.sqrt(f)),
    }
    if cfg.n_shared_experts:
        out["shared"] = _mlp_specs(cfg, cfg.n_shared_experts * cfg.d_ff_expert)
    return out


def _mamba_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    d, din, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    w = cfg.conv_width
    s = 1.0 / np.sqrt(d)
    return {
        "wz": ParamSpec((d, din), ("embed", "ssm_inner"), scale=s),
        "wx": ParamSpec((d, din), ("embed", "ssm_inner"), scale=s),
        "wB": ParamSpec((d, n), ("embed", "ssm_state"), scale=s),
        "wC": ParamSpec((d, n), ("embed", "ssm_state"), scale=s),
        "wdt": ParamSpec((d, h), ("embed", "ssm_heads"), scale=s),
        "conv_x": ParamSpec((w, din), ("conv", "ssm_inner"), scale=1.0 / np.sqrt(w)),
        "conv_B": ParamSpec((w, n), ("conv", "ssm_state"), scale=1.0 / np.sqrt(w)),
        "conv_C": ParamSpec((w, n), ("conv", "ssm_state"), scale=1.0 / np.sqrt(w)),
        "A_log": ParamSpec((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "D": ParamSpec((h,), ("ssm_heads",), init="ones", dtype="float32"),
        "dt_bias": ParamSpec((h,), ("ssm_heads",), init="zeros", dtype="float32"),
        "norm": ParamSpec((din,), ("ssm_inner",), init="ones", dtype="float32"),
        "wout": ParamSpec((din, d), ("ssm_inner", "embed"), scale=1.0 / np.sqrt(din)),
    }


def _block_specs(cfg: ModelConfig, spec: LayerSpec, cross: bool = False) -> Dict[str, Any]:
    d = cfg.d_model
    out: Dict[str, Any] = {
        "mixer_norm": ParamSpec((d,), ("embed",), init="ones", dtype="float32"),
    }
    if spec.mixer == "mamba":
        out["mamba"] = _mamba_specs(cfg)
    else:
        out["attn"] = _attn_specs(cfg)
    if cross:
        out["cross_norm"] = ParamSpec((d,), ("embed",), init="ones", dtype="float32")
        out["cross"] = _attn_specs(cfg, cross=True)
    if spec.ffn != "none":
        out["ffn_norm"] = ParamSpec((d,), ("embed",), init="ones", dtype="float32")
        out["ffn"] = _moe_specs(cfg) if spec.ffn == "moe" else _mlp_specs(cfg, cfg.dense_ff)
    return out


def _stack_specs(tree, repeat: int):
    """Prepend a 'layers' axis of size ``repeat`` to every leaf."""
    if repeat == 1:
        return tree
    return jax.tree_util.tree_map(
        lambda p: dataclasses.replace(p, shape=(repeat, *p.shape),
                                      axes=("layers", *p.axes)),
        tree, is_leaf=_is_spec)


def model_specs(cfg: ModelConfig) -> Dict[str, Any]:
    d, V = cfg.d_model, cfg.vocab
    out: Dict[str, Any] = {}
    # VLM keeps its text-embedding table (decode consumes generated *tokens*);
    # only the modality frontend is stubbed (prefill takes embeddings).
    if not cfg.embed_inputs or cfg.is_encoder_decoder or cfg.family == "vlm":
        out["embed"] = ParamSpec((V, d), ("vocab", "embed"), scale=1.0)
    out["groups"] = [
        _stack_specs(
            {"layers": [_block_specs(cfg, s, cross=cfg.is_encoder_decoder)
                        for s in g.period]},
            g.repeat)
        for g in cfg.groups()
    ]
    out["final_norm"] = ParamSpec((d,), ("embed",), init="ones", dtype="float32")
    if not cfg.tie_embeddings:
        out["lm_head"] = ParamSpec((d, V), ("embed", "vocab"), scale=1.0 / np.sqrt(d))
    if cfg.is_encoder_decoder:
        enc_period = [LayerSpec("attn", "dense")] * 1
        enc = {"layers": [_block_specs(cfg, enc_period[0])]}
        out["encoder"] = {
            "groups": [_stack_specs(enc, cfg.n_encoder_layers)],
            "final_norm": ParamSpec((d,), ("embed",), init="ones", dtype="float32"),
        }
    return out


# ------------------------------------------------------------ materialization

def _leaf_dtype(p: ParamSpec, cfg: ModelConfig):
    return jnp.dtype(p.dtype or cfg.param_dtype)


def abstract_params(cfg: ModelConfig):
    return jax.tree_util.tree_map(
        lambda p: jax.ShapeDtypeStruct(p.shape, _leaf_dtype(p, cfg)),
        model_specs(cfg), is_leaf=_is_spec)


def logical_axes(cfg: ModelConfig):
    return jax.tree_util.tree_map(lambda p: p.axes, model_specs(cfg), is_leaf=_is_spec)


def init_params(cfg: ModelConfig, key: jax.Array):
    """Concrete init. Per-leaf keys derive from the tree path (deterministic)."""
    specs = model_specs(cfg)
    leaves, treedef = jax.tree_util.tree_flatten_with_path(specs, is_leaf=_is_spec)

    def init_leaf(path, p: ParamSpec):
        dt = _leaf_dtype(p, cfg)
        if p.init == "zeros":
            return jnp.zeros(p.shape, dt)
        if p.init == "ones":
            return jnp.ones(p.shape, dt)
        path_str = jax.tree_util.keystr(path)
        k = jax.random.fold_in(key, np.uint32(abs(hash(path_str)) % (2**31)))
        scale = p.scale if p.scale is not None else 1.0 / np.sqrt(p.shape[0])
        return (jax.random.normal(k, p.shape, jnp.float32) * scale).astype(dt)

    vals = [init_leaf(path, p) for path, p in leaves]
    return jax.tree_util.tree_unflatten(treedef, vals)


def param_count(cfg: ModelConfig) -> int:
    specs = model_specs(cfg)
    return sum(int(np.prod(p.shape)) for p in
               jax.tree_util.tree_leaves(specs, is_leaf=_is_spec))


def active_param_count(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: top-k + shared experts only)."""
    total = param_count(cfg)
    if not cfg.n_experts:
        return total
    specs = model_specs(cfg)
    expert_leaves = []

    def visit(path, p):
        if isinstance(p, ParamSpec) and "experts" in p.axes:
            expert_leaves.append(int(np.prod(p.shape)))

    jax.tree_util.tree_map_with_path(visit, specs, is_leaf=_is_spec)
    expert_total = sum(expert_leaves)
    frac = cfg.experts_per_tok / cfg.n_experts
    return int(total - expert_total * (1.0 - frac))
