"""Model definitions: pattern-driven transformer/SSM/MoE stacks."""
from . import attention, layers, mamba2, model, moe, params
from .model import forward, make_cache
from .params import abstract_params, init_params, logical_axes, param_count

__all__ = [
    "attention", "layers", "mamba2", "model", "moe", "params",
    "forward", "make_cache",
    "abstract_params", "init_params", "logical_axes", "param_count",
]
