"""Mixture-of-Experts FFN: top-k routing with capacity-based dense dispatch.

Routing divisions go through the paper's unit: the router softmax and the
top-k renormalization are both ``division_modes`` call sites.

Dispatch is the capacity-C scatter/gather scheme (Switch/GShard style):
tokens sort into per-expert buffers of capacity C = ceil(T*k/E * cf); tokens
over capacity drop to the residual path. Expert weights carry the 'experts'
logical axis, so the same code runs EP (experts over a mesh axis, all-to-all
inserted by GSPMD at the scatter/gather) or expert-TP ('expert_mlp' sharded).

Load-balance aux loss (Switch eq. 4): aux = E * sum_e f_e * P_e.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import division_modes as dm


def _local_shard_count(T: int) -> int:
    """Batch-shard count for moe_dispatch='local' (1 without an active mesh)."""
    from repro.sharding.rules import active_mesh

    mesh = active_mesh()
    if mesh is None:
        return 1
    D = 1
    for ax in ("pod", "data"):
        D *= mesh.shape.get(ax, 1)
    return D if (D > 1 and T % D == 0 and T // D >= 1) else 1


def _dispatch_local(p, xt, probs, gates, idx, cfg: ModelConfig, D: int):
    """Shard-local gather-based dispatch: positions, capacity and every
    gather are computed within each data shard's row block, so GSPMD keeps
    the whole dispatch collective-free (the global-scatter formulation makes
    the partitioner replicate updates across shards). Capacity is per-shard
    (standard 'local capacity' semantics of production MoE systems)."""
    from repro.sharding.rules import shard_dim

    T, d = xt.shape
    E, k = cfg.n_experts, cfg.experts_per_tok
    Tl = T // D
    capacity = max(math.ceil(Tl * k / E * cfg.capacity_factor), min(Tl * k, 4))

    xr = shard_dim(xt.reshape(D, Tl, d), 0, "data")
    er = idx.reshape(D, Tl * k)                       # expert ids per row
    gr = gates.reshape(D, Tl * k)

    def row(x_row, e_row, g_row):
        order = jnp.argsort(e_row, stable=True)       # (Tl*k,)
        sorted_e = e_row[order]
        first = jnp.searchsorted(sorted_e, jnp.arange(E))
        counts = jnp.diff(jnp.append(first, Tl * k))
        # gather-based dispatch: source token for (expert, slot)
        slot = jnp.arange(capacity)
        src_sorted_idx = first[:, None] + slot[None, :]          # (E, C)
        valid = slot[None, :] < jnp.minimum(counts[:, None], capacity)
        src_choice = order[jnp.clip(src_sorted_idx, 0, Tl * k - 1)]
        src_token = src_choice // k                              # (E, C)
        buf = jnp.where(valid[..., None], x_row[src_token], 0)   # (E, C, d)
        # return-trip bookkeeping: position of each (token, choice)
        pos_sorted = jnp.arange(Tl * k) - first[sorted_e]
        pos = jnp.zeros((Tl * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
        keep = pos < capacity
        return buf, pos, keep

    buf, pos, keep = jax.vmap(row)(xr, er, gr)         # (D,E,C,d),(D,Tlk),(D,Tlk)
    buf = shard_dim(buf, 0, "data")

    h = jnp.einsum("recd,edf->recf", buf, p["wi"])
    g = jax.nn.silu(jnp.einsum("recd,edf->recf", buf, p["wg"]).astype(jnp.float32))
    eo = jnp.einsum("recf,efd->recd", g.astype(h.dtype) * h, p["wo"])
    eo = shard_dim(eo, 0, "data")

    def combine(eo_row, e_row, pos_row, keep_row, g_row):
        tok = eo_row[e_row, jnp.clip(pos_row, 0, capacity - 1)]  # (Tl*k, d)
        return tok * (g_row * keep_row).astype(tok.dtype)[:, None]

    tok_out = jax.vmap(combine)(eo, er, pos, keep, gr)  # (D, Tl*k, d)
    out = tok_out.reshape(D, Tl, k, d).sum(axis=2).reshape(T, d)

    counts_f = jax.vmap(lambda e, kp: jnp.zeros((E,), jnp.float32).at[e].add(
        kp.astype(jnp.float32)))(er, keep).sum(axis=0)
    return out, counts_f


def moe_ffn(p: Dict, x, cfg: ModelConfig) -> Tuple[jax.Array, jax.Array]:
    """x: (b, s, d) -> (out, aux_loss)."""
    b, s, d = x.shape
    E, k = cfg.n_experts, cfg.experts_per_tok
    T = b * s
    xt = x.reshape(T, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = dm.softmax(logits, axis=-1, cfg=cfg.division)          # (T, E)
    gate_vals, idx = jax.lax.top_k(probs, k)                       # (T, k)
    # top-k renormalization — another divider site
    denom = jnp.sum(gate_vals, axis=-1, keepdims=True)
    gates = gate_vals * dm.recip(denom, cfg.division)              # (T, k)

    # Capacity floor: tiny token counts (decode steps) get no-drop capacity so
    # serving is deterministic; large batches use the standard cf bound.
    if cfg.moe_dispatch == "local":
        D = _local_shard_count(T)
        out, counts = _dispatch_local(p, xt, probs, gates, idx, cfg, D)
        if cfg.n_shared_experts:
            from .layers import gated_mlp
            out = out + gated_mlp(p["shared"], xt)
        f_e = counts / (T * k) * E
        P_e = jnp.mean(probs, axis=0)
        aux = E * jnp.sum(f_e * P_e) * cfg.router_aux_weight
        return out.reshape(b, s, d), aux

    capacity = max(math.ceil(T * k / E * cfg.capacity_factor), min(T * k, 8))

    flat_e = idx.reshape(T * k)                                    # expert ids
    flat_g = gates.reshape(T * k)
    if cfg.moe_dispatch == "sort":
        # megablocks-style: stable-sort by expert, position = rank within the
        # expert's run. O(Tk log Tk); same first-come-first-served drops as
        # the cumsum scheme, but no O(Tk*E) global cumsum (which XLA models
        # as reduce-window and SPMD executes near-quadratically).
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        first = jnp.searchsorted(sorted_e, jnp.arange(E))          # (E,)
        pos_sorted = jnp.arange(T * k) - first[sorted_e]
        flat_pos = jnp.zeros((T * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
    else:
        onehot_pos = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)    # (T*k, E)
        pos_in_e = jnp.cumsum(onehot_pos, axis=0) * onehot_pos     # 1-based
        flat_pos = jnp.sum(pos_in_e, axis=-1) - 1                  # (T*k,)
    keep = (flat_pos >= 0) & (flat_pos < capacity)
    flat_pos = jnp.clip(flat_pos, 0, capacity - 1)

    # dispatch: (E, C, d)
    xr = jnp.repeat(xt, k, axis=0)                                 # (T*k, d)
    contrib = jnp.where(keep[:, None], xr, 0).astype(x.dtype)
    buf = jnp.zeros((E, capacity, d), x.dtype)
    buf = buf.at[flat_e, flat_pos].add(contrib)

    # expert compute: gated MLP batched over experts
    h = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    g = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["wg"]).astype(jnp.float32))
    eo = jnp.einsum("ecf,efd->ecd", g.astype(h.dtype) * h, p["wo"])

    # combine
    tok_out = eo[flat_e, flat_pos]                                 # (T*k, d)
    tok_out = tok_out * (flat_g * keep).astype(tok_out.dtype)[:, None]
    out = tok_out.reshape(T, k, d).sum(axis=1)

    if cfg.n_shared_experts:
        from .layers import gated_mlp
        out = out + gated_mlp(p["shared"], xt)

    # load-balance aux (scatter-add counts; no (T*k, E) one-hot materialized)
    counts = jnp.zeros((E,), jnp.float32).at[flat_e].add(
        keep.astype(jnp.float32))
    f_e = counts / (T * k) * E
    P_e = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(f_e * P_e) * cfg.router_aux_weight

    return out.reshape(b, s, d), aux
