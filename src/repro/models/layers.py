"""Shared layer primitives. Every division/rsqrt goes through core.division_modes."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.configs.base import ModelConfig


def rms_norm(x, w, div: dm.DivisionConfig, eps: float = 1e-6):
    """RMSNorm through the division unit's consumer dispatch: the Pallas
    modes run the fused kernel, everything else the jnp twin — one knob."""
    return dm.rmsnorm(x, w, div, eps=eps)


def rope(x, positions, theta: float):
    """Rotary embeddings. x: (B, S, H, hd); positions: (B, S) int32."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1).astype(x.dtype)


def gated_mlp(p, x):
    """SwiGLU MLP: wo(silu(wg x) * (wi x))."""
    h = jnp.einsum("...d,df->...f", x, p["wi"])
    g = jax.nn.silu(jnp.einsum("...d,df->...f", x, p["wg"]).astype(jnp.float32))
    return jnp.einsum("...f,fd->...d", (g.astype(h.dtype) * h), p["wo"])


def embed_tokens(embed, tokens, cfg: ModelConfig):
    return jnp.take(embed, tokens, axis=0)


def lm_logits(params, x, cfg: ModelConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return jnp.einsum("...d,dv->...v", x, head,
                      preferred_element_type=jnp.float32)
