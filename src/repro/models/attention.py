"""Attention: GQA full/causal, sliding-window (block-local), cross, and decode.

Softmax denominators route through the paper's division unit
(core.division_modes.softmax). The 1/sqrt(head_dim) score scale is a
compile-time constant (no runtime division).

Memory strategy: full attention is query-chunked (scan over query blocks,
keys whole) so 32k-token prefill never materializes an S x S score tensor;
sliding-window attention is block-local (each W-sized query block sees the
previous and current key blocks) making it O(S*W).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.configs.base import ModelConfig

NEG_INF = -1e30


def _proj_qkv(p, x, cfg: ModelConfig):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    return q, k, v


def _repeat_kv(k, n_rep: int):
    from repro.sharding.rules import shard_dim

    if n_rep == 1:
        return shard_dim(k, 2)
    b, s, kv, hd = k.shape
    k = jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                         ).reshape(b, s, kv * n_rep, hd)
    # GQA head-repeat: pin the repeated heads to the model axis, else GSPMD
    # replicates the score tensors and inserts full-size all-reduces.
    return shard_dim(k, 2)


def _sdpa(q, k, v, mask, div: dm.DivisionConfig, scale: float):
    """q: (b,qs,h,hd), k/v: (b,ks,h,hd), mask: broadcastable to (b,h,qs,ks)."""
    from repro.sharding.rules import shard_dim

    q = shard_dim(q, 2)
    scores = jnp.einsum("bqhk,bthk->bhqt", q, k,
                        preferred_element_type=jnp.float32) * scale
    scores = shard_dim(scores, 1)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = dm.softmax(scores, axis=-1, cfg=div)
    out = jnp.einsum("bhqt,bthk->bqhk", probs.astype(v.dtype), v)
    return shard_dim(out, 2)


def full_attention(p, x, positions, cfg: ModelConfig, *, causal: bool = True,
                   kv_override: Optional[Tuple] = None, q_positions=None):
    """Training/prefill full attention, query-chunked above cfg.attn_chunk."""
    b, s, d = x.shape
    div = cfg.division
    scale = 1.0 / math.sqrt(cfg.head_dim)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is not None:  # cross attention: k/v precomputed, no rope
        k, v = kv_override
    else:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        q = rope_apply(q, positions, cfg)
        k = rope_apply(k, positions, cfg)
    k = _repeat_kv(k, cfg.q_per_kv)
    v = _repeat_kv(v, cfg.q_per_kv)
    t = k.shape[1]

    def attend_chunk(qc, qpos_c):
        if causal and kv_override is None:
            mask = qpos_c[:, None, :, None] >= positions[:, None, None, :]
        else:
            mask = jnp.ones((1, 1, 1, 1), bool)
        out = _sdpa(qc, k, v, mask, div, scale)
        return out

    chunk = cfg.attn_chunk
    if s <= chunk or s % chunk != 0:
        out = attend_chunk(q, positions)
    else:
        nb = s // chunk
        qs = q.reshape(b, nb, chunk, *q.shape[2:])
        ps = positions.reshape(b, nb, chunk)

        def body(_, xs):
            qc, pc = xs
            return None, attend_chunk(qc, pc)

        # scan over query chunks: (nb, b, chunk, ...)
        _, outs = jax.lax.scan(body, None,
                               (jnp.moveaxis(qs, 1, 0), jnp.moveaxis(ps, 1, 0)),
                               unroll=nb if cfg.scan_unroll else 1)
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, *q.shape[2:])
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"])


def sliding_attention(p, x, positions, cfg: ModelConfig):
    """Block-local sliding-window attention: O(S*W) compute and memory."""
    b, s, d = x.shape
    w = cfg.sliding_window
    div = cfg.division
    scale = 1.0 / math.sqrt(cfg.head_dim)
    q, k, v = _proj_qkv(p, x, cfg)
    q = rope_apply(q, positions, cfg)
    k = rope_apply(k, positions, cfg)
    k = _repeat_kv(k, cfg.q_per_kv)
    v = _repeat_kv(v, cfg.q_per_kv)
    if s <= w:  # degenerate: plain causal attention
        mask = positions[:, None, :, None] >= positions[:, None, None, :]
        out = _sdpa(q, k, v, mask, div, scale)
        return jnp.einsum("bqhk,hkd->bqd", out, p["wo"])
    assert s % w == 0, f"seq {s} must be a multiple of window {w}"
    nb = s // w
    h, hd = q.shape[2], q.shape[3]
    qb = q.reshape(b, nb, w, h, hd)
    kb = k.reshape(b, nb, w, h, hd)
    vb = v.reshape(b, nb, w, h, hd)
    zeros = jnp.zeros_like(kb[:, :1])
    kprev = jnp.concatenate([zeros, kb[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([kprev, kb], axis=2)  # (b, nb, 2w, h, hd)
    v2 = jnp.concatenate([vprev, vb], axis=2)
    qpos = jnp.arange(w)
    kpos = jnp.arange(2 * w) - w
    base = (qpos[:, None] >= kpos[None, :]) & (qpos[:, None] - kpos[None, :] < w)
    first = kpos[None, :] >= 0  # block 0 must not see the phantom prev block
    bidx = jnp.arange(nb)
    mask = base[None, :, :] & (first | (bidx[:, None, None] > 0))  # (nb, w, 2w)
    from repro.sharding.rules import shard_dim

    qb = shard_dim(qb, 3)
    k2 = shard_dim(k2, 3)
    v2 = shard_dim(v2, 3)
    scores = jnp.einsum("bnqhk,bnthk->bnhqt", qb, k2,
                        preferred_element_type=jnp.float32) * scale
    scores = shard_dim(scores, 2)
    scores = jnp.where(mask[None, :, None, :, :], scores, NEG_INF)
    probs = dm.softmax(scores, axis=-1, cfg=div)
    out = jnp.einsum("bnhqt,bnthk->bnqhk", probs.astype(v2.dtype), v2)
    out = shard_dim(out, 3)
    out = out.reshape(b, s, h, hd)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"])


def rope_apply(x, positions, cfg: ModelConfig):
    from .layers import rope

    return rope(x, positions, cfg.rope_theta)


# --------------------------------------------------------------------- cache

def init_cache_attn(cfg: ModelConfig, batch: int, max_len: int, window: int = 0,
                    dtype=jnp.bfloat16):
    length = window if window > 0 else max_len
    kv = cfg.n_kv_heads
    shape = (batch, length, kv, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def abstract_cache_attn(cfg: ModelConfig, batch: int, max_len: int, window: int = 0,
                        dtype=jnp.bfloat16):
    length = window if window > 0 else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jax.ShapeDtypeStruct(shape, dtype),
            "v": jax.ShapeDtypeStruct(shape, dtype)}


def decode_positions(pos, batch: int):
    """Normalize decode ``pos`` to a (batch,) int32 vector.

    Accepts the legacy scalar (all requests at the same position) or a
    per-request (batch,) vector — the serving engine's padded-prompt fix:
    request i's tokens live at absolute positions 0..pos_i, so each slot
    writes, ropes, and masks at its own position.
    """
    pos_v = jnp.reshape(jnp.asarray(pos, jnp.int32), (-1,))
    if pos_v.shape[0] == 1:
        pos_v = jnp.broadcast_to(pos_v, (batch,))
    return pos_v


def decode_attention(p, x, cache, pos, cfg: ModelConfig, *, window: int = 0,
                     kv_override: Optional[Tuple] = None):
    """One-token decode. x: (b, 1, d); cache k/v: (b, L, kv, hd); pos: scalar
    or per-request (b,) vector of absolute positions.

    Full-attention layers index the cache at pos; sliding-window layers treat
    the cache as a ring buffer of size W (softmax is permutation-invariant, so
    ring order needs no unrotation). With a per-request pos vector each
    request writes its own slot, and the validity mask excludes every cache
    slot the request has not written/prefilled — in a padded batch the pad
    slots at positions >= len(prompt_i) are never attended (they sit above
    pos_i until the request's own generated tokens overwrite them).
    """
    b, one, d = x.shape
    div = cfg.division
    scale = 1.0 / math.sqrt(cfg.head_dim)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if kv_override is not None:
        k_all = _repeat_kv(kv_override[0], cfg.q_per_kv)
        v_all = _repeat_kv(kv_override[1], cfg.q_per_kv)
        mask = jnp.ones((1, 1, 1, 1), bool)
        out = _sdpa(q, k_all, v_all, mask, div, scale)
        return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]), cache
    pos_v = decode_positions(pos, b)
    posv = pos_v[:, None]
    q = rope_apply(q, posv, cfg)
    k_new = rope_apply(jnp.einsum("bsd,dhk->bshk", x, p["wk"]), posv, cfg)
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    L = cache["k"].shape[1]
    slot_v = jnp.mod(pos_v, L) if window > 0 else pos_v
    bidx = jnp.arange(b)
    k_c = cache["k"].at[bidx, slot_v].set(k_new[:, 0].astype(cache["k"].dtype))
    v_c = cache["v"].at[bidx, slot_v].set(v_new[:, 0].astype(cache["v"].dtype))
    k_all = _repeat_kv(k_c, cfg.q_per_kv)
    v_all = _repeat_kv(v_c, cfg.q_per_kv)
    idx = jnp.arange(L)
    if window == 0:
        valid = idx[None, :] <= pos_v[:, None]
    else:
        # Ring invariant: slot j holds the latest position p <= pos_i with
        # p % W == j (prefill builds rings the same way). held < 0 marks a
        # slot whose position would predate the sequence — never written.
        held = pos_v[:, None] - jnp.mod(pos_v[:, None] - idx[None, :], L)
        valid = held >= 0
    mask = valid[:, None, None, :]
    out = _sdpa(q, k_all, v_all, mask, div, scale)
    return jnp.einsum("bqhk,hkd->bqd", out, p["wo"]), {"k": k_c, "v": v_c}
