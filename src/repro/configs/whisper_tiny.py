"""whisper-tiny [audio]: enc-dec, 4+4L d_model=384 6H (kv=6) d_ff=1536 vocab=51865.

arXiv:2212.04356. The conv frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, 1500, 384). Decoder self-attention is causal
with a KV cache; cross-attention K/V are projected once at prefill and cached.
Deviation noted in DESIGN.md: gated-SiLU MLP and RoPE replace Whisper's GELU
MLP and learned positions (framework-uniform blocks).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,
    d_model=384,
    n_heads=6, n_kv_heads=6, head_dim=64,
    d_ff=1536,
    vocab=51_865,
    is_encoder_decoder=True,
    n_encoder_layers=4,
    encoder_seq=1500,
    tie_embeddings=True,
    train_microbatch_size=16,
    notes="heads=6 not divisible by model axis 16 -> attention replicated "
          "over 'model'; mlp dim 1536 shards (96/shard).",
)

SMOKE_CONFIG = ModelConfig(
    name="whisper-smoke",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128,
    vocab=256,
    is_encoder_decoder=True,
    n_encoder_layers=2,
    encoder_seq=32,
    tie_embeddings=True,
    remat=False,
)
