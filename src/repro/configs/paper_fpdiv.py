"""paper-fpdiv: the paper's own demo config — a ~124M dense LM whose every
division site (attention softmax, RMSNorm, Adam) runs the Taylor-series
division unit at paper-faithful settings (n=5, 53-bit table, 'paper'
powering-unit schedule). Used by examples/ and the e2e benchmark.
"""
from repro.configs.base import ModelConfig
from repro.core.division_modes import DivisionConfig

CONFIG = ModelConfig(
    name="paper-fpdiv",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12, n_kv_heads=12, head_dim=64,
    d_ff=2048,
    vocab=32_000,
    division=DivisionConfig(mode="taylor", precision_bits=24, n_iters=2,
                            schedule="paper"),
    train_microbatch_size=16,
)

SMOKE_CONFIG = ModelConfig(
    name="paper-fpdiv-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128,
    vocab=256,
    division=DivisionConfig(mode="taylor", precision_bits=24, n_iters=2,
                            schedule="paper"),
    remat=False,
)
