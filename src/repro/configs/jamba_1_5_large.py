"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8) d_ff=24576
vocab=65536, MoE 16e top-2, Mamba:attn 1:7 interleave (arXiv:2403.19887).

Layer pattern (period 8, scanned 9x): attention at position 4 of each
8-block, Mamba elsewhere; MoE FFN on odd layers, dense FFN on even.
Totals ~397B params, ~94B active — matches the released model.

Distribution: fully-sharded (ZeRO-ish) 2D layout — 'experts' over data (16
experts / 16 rows), 'expert_mlp' + heads/ssm over model, 'embed' over data
for the dense matrices. bf16 optimizer moments keep the per-chip footprint
inside a v5e's 16 GB: params ~3.1 GB + m,v ~6.2 GB + activations (microbatch
1, remat) < 16 GB.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64, n_kv_heads=8, head_dim=128,
    d_ff=24_576,
    vocab=65_536,
    attn_period=8, attn_offset=4,
    moe_period=2, moe_offset=1,
    n_experts=16, experts_per_tok=2,
    d_ff_expert=24_576,
    ssm_state=128, ssm_heads=128, ssm_head_dim=128, d_inner=16_384,
    opt_state_dtype="bfloat16",
    sharding_rules={
        "embed": "data", "experts": "data", "expert_mlp": "model",
        "mlp": "model", "heads": "model", "vocab": "model",
        "ssm_inner": "model", "ssm_heads": "model",
    },
    train_microbatch_size=1,
)

SMOKE_CONFIG = ModelConfig(
    name="jamba-smoke",
    family="hybrid",
    n_layers=8,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab=256,
    attn_period=8, attn_offset=4,
    moe_period=2, moe_offset=1,
    n_experts=4, experts_per_tok=2,
    d_ff_expert=128,
    ssm_state=16, ssm_heads=4, ssm_head_dim=16, d_inner=64,
    ssm_chunk=16,
    remat=False,
)
