"""Config system: model configs, shape configs, sharding rules, registry.

Every assigned architecture is a ``ModelConfig`` in its own module under
``repro.configs``; ``get_config(arch_id)`` resolves it. Shapes are the four
assigned (seq_len, global_batch) cells; ``input_specs`` builds the
ShapeDtypeStruct stand-ins the dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

from repro.core.division_modes import DivisionConfig

# ----------------------------------------------------------------- layer spec

MIXERS = ("attn", "swa", "mamba")
FFNS = ("dense", "moe", "none")


@dataclass(frozen=True)
class LayerSpec:
    mixer: str
    ffn: str

    def __post_init__(self):
        assert self.mixer in MIXERS and self.ffn in FFNS


@dataclass(frozen=True)
class Group:
    """``repeat`` copies of the layer ``period`` — lowered as one lax.scan."""

    period: Tuple[LayerSpec, ...]
    repeat: int


# -------------------------------------------------------------- model config

@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    # --- layer pattern ---
    attn_period: int = 1           # attention every k-th layer (hybrid); 1 = all
    attn_offset: int = 0
    moe_period: int = 0            # 0 = no MoE; k = MoE ffn every k-th layer
    moe_offset: int = 0
    first_dense: int = 0           # leading layers forced dense-FFN (deepseek)
    # --- attention ---
    sliding_window: int = 0        # >0 enables SWA layers
    global_every: int = 0          # 1 global layer per this many (gemma 5:1 -> 6)
    rope_theta: float = 10_000.0
    # --- moe ---
    n_experts: int = 0
    experts_per_tok: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    d_ff_dense: int = 0            # dense-FFN width when it differs (deepseek)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_dispatch: str = "cumsum"   # cumsum (GShard-style positions) | sort
                                   # (megablocks-style; O(T log T), avoids the
                                   # global cumsum that blows up under SPMD)
    # --- ssm (mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    d_inner: int = 0
    ssm_chunk: int = 256
    conv_width: int = 4
    # --- enc-dec ---
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 0           # stub frontend: precomputed frames/patches
    # --- io ---
    embed_inputs: bool = False     # vlm/audio stub: inputs are embeddings
    tie_embeddings: bool = False
    # --- numerics / distribution ---
    param_dtype: str = "bfloat16"
    opt_state_dtype: str = "float32"
    norm_eps: float = 1e-6
    division: DivisionConfig = field(default_factory=lambda: DivisionConfig(mode="taylor"))
    sharding_rules: Dict[str, Optional[str]] = field(default_factory=dict)
    remat: bool = True
    train_microbatch_size: int = 4  # sequences per data-shard per microbatch
    attn_chunk: int = 2048          # query-chunked attention threshold/size
    use_flash_kernel: bool = False  # fused flash-attention (kernels/
                                    # flash_attention.py) — zeroes the score
                                    # term of the HBM model; TPU fast path
    scan_unroll: bool = False       # dry-run cost probe: unroll scans so XLA
                                    # cost_analysis sees every trip (it counts
                                    # while-loop bodies exactly once)
    group_repeat_override: Optional[Tuple[int, ...]] = None  # cost-probe knob
    notes: str = ""

    # -------------------------------------------------- derived layer pattern
    def layer_specs(self) -> List[LayerSpec]:
        specs = []
        for i in range(self.n_layers):
            if self.family in ("ssm",):
                mixer = "mamba"
            elif self.attn_period > 1:
                mixer = "attn" if i % self.attn_period == self.attn_offset else "mamba"
            elif self.sliding_window > 0 and self.global_every > 0:
                mixer = "attn" if i % self.global_every == self.global_every - 1 else "swa"
            else:
                mixer = "attn"
            if self.family == "ssm":
                ffn = "none"
            elif self.moe_period > 0 and i >= self.first_dense \
                    and i % self.moe_period == self.moe_offset:
                ffn = "moe"
            else:
                ffn = "dense"
            specs.append(LayerSpec(mixer, ffn))
        return specs

    def groups(self) -> List[Group]:
        """Greedy periodic grouping: find the shortest period p such that the
        layer pattern is p-periodic, then scan over n_layers/p repeats.

        ``group_repeat_override`` swaps the repeat counts (dry-run cost probes
        lower tiny 1-2 repeat stacks and reconstruct full-depth cost affinely;
        XLA's cost_analysis counts loop bodies once, so depth must be probed,
        not trusted)."""
        base = self._groups_base()
        if self.group_repeat_override is not None:
            assert len(self.group_repeat_override) == len(base)
            return [Group(g.period, r)
                    for g, r in zip(base, self.group_repeat_override)]
        return base

    def _groups_base(self) -> List[Group]:
        specs = self.layer_specs()
        n = len(specs)
        lead = specs[: self.first_dense]
        rest = specs[self.first_dense:]
        out: List[Group] = []
        if lead:
            out.append(Group(tuple(lead), 1))
        m = len(rest)
        for p in range(1, m + 1):
            if m % p == 0 and all(rest[i] == rest[i % p] for i in range(m)):
                out.append(Group(tuple(rest[:p]), m // p))
                return out
        out.append(Group(tuple(rest), 1))
        return out

    @property
    def dense_ff(self) -> int:
        return self.d_ff_dense or self.d_ff

    @property
    def q_per_kv(self) -> int:
        return self.n_heads // self.n_kv_heads


# -------------------------------------------------------------- shape config

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524_288, 1),
}

# Archs whose *every* attention layer is full attention skip long_500k
# (sub-quadratic requirement); SSM / hybrid / sliding-window archs run it.
SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def long_context_ok(cfg: ModelConfig) -> bool:
    if cfg.family in SUBQUADRATIC_FAMILIES:
        return True
    if cfg.sliding_window > 0 and cfg.global_every > 0:
        return True  # gemma-style mostly-local attention
    return False


def shapes_for(cfg: ModelConfig) -> List[ShapeConfig]:
    out = [LM_SHAPES["train_4k"], LM_SHAPES["prefill_32k"], LM_SHAPES["decode_32k"]]
    if long_context_ok(cfg):
        out.append(LM_SHAPES["long_500k"])
    return out


# ---------------------------------------------------------------- registry

ARCH_IDS = [
    "mamba2_780m",
    "granite_8b",
    "llama3_8b",
    "gemma3_12b",
    "tinyllama_1_1b",
    "llava_next_mistral_7b",
    "whisper_tiny",
    "jamba_1_5_large",
    "moonshot_v1_16b_a3b",
    "deepseek_moe_16b",
    "paper_fpdiv",
]


def canon(arch: str) -> str:
    return arch.replace("-", "_").replace(".", "_")


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.SMOKE_CONFIG


# Default logical-axis -> mesh-axis rules. Arch configs override per-axis.
DEFAULT_RULES: Dict[str, Optional[str]] = {
    "embed": None,
    "heads": "model",
    "kv_heads": "model",      # dropped automatically when not divisible
    "head_dim": None,
    "mlp": "model",
    "vocab": "model",
    "experts": "model",
    "expert_mlp": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "ssm_state": None,
    "conv": None,
    "layers": None,
}


def rules_for(cfg: ModelConfig) -> Dict[str, Optional[str]]:
    rules = dict(DEFAULT_RULES)
    rules.update(cfg.sharding_rules)
    return rules
