"""mamba2-780m [ssm]: 48L d_model=1536, attn-free, vocab=50280, ssm_state=128.

SSD (state-space duality), arXiv:2405.21060. d_inner = 2*d_model, head_dim 64
=> 48 SSM heads. Tied embeddings (official mamba2 ties). The paper's division
unit applies to the gated RMSNorm rsqrt and the optimizer; pure-SSM blocks
have no softmax (noted in DESIGN.md §Arch-applicability).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0, n_kv_heads=1, head_dim=0,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_heads=48,
    ssm_head_dim=64,
    d_inner=3072,
    tie_embeddings=True,
    train_microbatch_size=8,
    notes="attn-free; long_500k runs (O(1) state); vocab 50280 not divisible "
          "by 16 -> embedding replicated (77M bf16, 154MB).",
)

SMOKE_CONFIG = ModelConfig(
    name="mamba2-smoke",
    family="ssm",
    n_layers=4,
    d_model=64,
    n_heads=0, n_kv_heads=1, head_dim=0,
    d_ff=0,
    vocab=257,
    ssm_state=16,
    ssm_heads=4,
    ssm_head_dim=16,
    d_inner=64,
    ssm_chunk=16,
    tie_embeddings=True,
    remat=False,
)
