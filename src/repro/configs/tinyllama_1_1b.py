"""tinyllama-1.1b [dense]: 22L d_model=2048 32H (GQA kv=4) d_ff=5632 vocab=32000.

Llama2-architecture small model (arXiv:2401.02385). head_dim 64.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b",
    family="dense",
    n_layers=22,
    d_model=2048,
    n_heads=32, n_kv_heads=4, head_dim=64,
    d_ff=5632,
    vocab=32_000,
    train_microbatch_size=8,
)

SMOKE_CONFIG = ModelConfig(
    name="tinyllama-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=8, n_kv_heads=2, head_dim=8,
    d_ff=128,
    vocab=256,
    remat=False,
)
