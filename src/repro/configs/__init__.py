"""Arch configs: one module per assigned architecture + the paper demo config."""
from .base import (ARCH_IDS, LM_SHAPES, ModelConfig, ShapeConfig, get_config,
                   get_smoke_config, long_context_ok, rules_for, shapes_for)

__all__ = [
    "ARCH_IDS", "LM_SHAPES", "ModelConfig", "ShapeConfig", "get_config",
    "get_smoke_config", "long_context_ok", "rules_for", "shapes_for",
]
