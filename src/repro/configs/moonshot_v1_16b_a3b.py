"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16) d_ff=1408
vocab=163840, MoE 64e top-6 (hf:moonshotai/Moonlight-16B-A3B).

DeepSeek-family fine-grained MoE: 64 routed experts top-6 + 2 shared experts,
first layer dense (d_ff 11264), per the Moonlight architecture. NOTE: the
assigned spec pins 48 layers; with 64x1408 experts that totals ~28B
parameters rather than the 16B the name suggests — we follow the assigned
spec exactly and record the discrepancy here and in EXPERIMENTS.md.

kv=16 == model-axis size, so KV heads shard fully (no replication).
EP: 'experts' over data (4 experts/row), expert_mlp over model (88/shard).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408,
    vocab=163_840,
    moe_period=1, moe_offset=0,
    first_dense=1,
    n_experts=64, experts_per_tok=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    d_ff_dense=11_264,
    sharding_rules={"experts": "data", "expert_mlp": "model"},
    train_microbatch_size=4,
)

SMOKE_CONFIG = ModelConfig(
    name="moonshot-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=64,
    vocab=512,
    moe_period=1, moe_offset=0,
    first_dense=1,
    n_experts=8, experts_per_tok=2,
    n_shared_experts=2,
    d_ff_expert=64,
    d_ff_dense=128,
    remat=False,
)
