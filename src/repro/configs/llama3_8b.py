"""llama3-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

arXiv:2407.21783. 128k vocab => the lm_head matmul and CE logsumexp dominate
short-seq memory; vocab shards over the model axis (128256/16 = 8016).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336,
    vocab=128_256,
    rope_theta=500_000.0,
    train_microbatch_size=4,
)

SMOKE_CONFIG = ModelConfig(
    name="llama3-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab=512,
    rope_theta=500_000.0,
    remat=False,
)
