"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144.

5:1 local:global attention (sliding window 1024, 1 global layer per 6),
head_dim 256 (gemma's q dim 4096 != d_model), tied embeddings, 128k-class
context via the mostly-local pattern => long_500k runs (decode against W-sized
ring caches on 40 of 48 layers).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=15_360,
    vocab=262_144,
    sliding_window=1024,
    global_every=6,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    train_microbatch_size=2,
)

SMOKE_CONFIG = ModelConfig(
    name="gemma3-smoke",
    family="dense",
    n_layers=6,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab=512,
    sliding_window=16,
    global_every=3,
    tie_embeddings=True,
    remat=False,
)
