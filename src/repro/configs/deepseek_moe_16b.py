"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400, 2 shared + 64 routed top-6, fine-grained (arXiv:2401.06066).

First layer dense (d_ff 10944), remaining 27 layers fine-grained MoE.
Totals ~16.4B params / ~2.8B active. Sharding as moonshot (EP over data,
expert-mlp over model).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=1408,
    vocab=102_400,
    moe_period=1, moe_offset=0,
    first_dense=1,
    n_experts=64, experts_per_tok=6,
    n_shared_experts=2,
    d_ff_expert=1408,
    d_ff_dense=10_944,
    sharding_rules={"experts": "data", "expert_mlp": "model"},
    train_microbatch_size=4,
)

SMOKE_CONFIG = ModelConfig(
    name="deepseek-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=64,
    vocab=512,
    moe_period=1, moe_offset=0,
    first_dense=1,
    n_experts=8, experts_per_tok=2,
    n_shared_experts=2,
    d_ff_expert=64,
    d_ff_dense=128,
    remat=False,
)
