"""llava-next-mistral-7b [vlm]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.

Backbone only (hf:llava-hf/llava-v1.6-mistral-7b-hf): the anyres vision tower
is a STUB — input_specs() provides precomputed patch+text embeddings
(B, S, d_model), per the assignment. embed_inputs=True => no input embedding
table; the untied lm_head maps d_model -> 32000.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336,
    vocab=32_000,
    embed_inputs=True,
    train_microbatch_size=4,
)

SMOKE_CONFIG = ModelConfig(
    name="llava-smoke",
    family="vlm",
    n_layers=3,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab=256,
    embed_inputs=True,
    remat=False,
)
