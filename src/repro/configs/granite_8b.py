"""granite-8b [dense]: 36L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=49152.

Llama-architecture code model (arXiv:2405.04324). kv_heads=8 < model axis 16
=> KV replicated over the model axis (divisibility drop), Megatron-style.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-8b",
    family="dense",
    n_layers=36,
    d_model=4096,
    n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14_336,
    vocab=49_152,
    train_microbatch_size=4,
)

SMOKE_CONFIG = ModelConfig(
    name="granite-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128,
    vocab=256,
    remat=False,
)
