from .engine import ServingEngine, decode_step, pad_cache_to, prefill

__all__ = ["ServingEngine", "decode_step", "pad_cache_to", "prefill"]
