from .engine import (Request, ServingEngine, decode_step, pad_cache_to,
                     prefill)

__all__ = ["Request", "ServingEngine", "decode_step", "pad_cache_to",
           "prefill"]
