"""Serving engine: prefill + batched decode with per-layer-kind caches.

Cache layout mirrors the model's grouped scan structure; sizing is
layer-aware (full-length KV for global attention, W-sized ring buffers for
sliding-window layers, O(1) SSM/conv state for mamba). ``ServingEngine``
drives continuous batched decode: prefill one request at a time into its
batch slot, decode all active slots in lockstep (one jit'd step), release on
EOS/length — the standard static-batching serving loop, deterministic by
construction.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import forward, make_cache


def prefill(cfg: ModelConfig, params, tokens, *, enc_embeds=None, embeds=None):
    """Returns (last_logits (B, V), cache). Seq must respect window/chunk
    alignment (engine pads requests to the alignment)."""
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = enc_embeds
    if cfg.embed_inputs and not cfg.is_encoder_decoder:
        logits, cache, _ = forward(cfg, params, embeds=embeds, mode="prefill", **kw)
    else:
        logits, cache, _ = forward(cfg, params, tokens=tokens, mode="prefill", **kw)
    return logits[:, -1], cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step. tokens: (B, 1); pos: scalar int32. -> (logits, cache)."""
    logits, new_cache, _ = forward(cfg, params, tokens=tokens, cache=cache,
                                   pos=pos, mode="decode")
    return logits[:, 0], new_cache


def pad_cache_to(cache, from_len: int, to_len: int):
    """Grow full-attention KV caches (seq dim == from_len) to to_len."""
    def pad(a):
        if a.ndim >= 3 and a.shape[-3] == from_len:
            padw = [(0, 0)] * a.ndim
            padw[-3] = (0, to_len - from_len)
            return jnp.pad(a, padw)
        return a
    return jax.tree_util.tree_map(pad, cache)


@dataclasses.dataclass
class Request:
    tokens: List[int]
    max_new: int = 32
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Greedy-decoding static-batch engine over the smoke/full configs."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self._decode = jax.jit(
            lambda c, t, p: decode_step(cfg, params, c, t, p))

    def generate_batch(self, prompts, max_new: int = 32):
        """Batched requests: right-align-pad prompts to a common aligned
        length, prefill once, decode all slots in lockstep (static batching).
        Returns a list of generated-token lists."""
        import numpy as np

        cfg = self.cfg
        B = len(prompts)
        s_max = max(len(p) for p in prompts)
        align = max(cfg.sliding_window or 1,
                    cfg.ssm_chunk if cfg.family in ("ssm", "hybrid") else 1, 1)
        pad_to = -(-s_max // align) * align
        toks = np.zeros((B, pad_to), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            toks[i, len(p):] = p[-1]  # edge-pad
        toks = jnp.asarray(toks)
        last_logits, cache = prefill(cfg, self.params, toks)
        cache = pad_cache_to(cache, pad_to, self.max_len)
        pos = pad_to
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        outs = [[] for _ in range(B)]
        for _ in range(max_new):
            for i in range(B):
                outs[i].append(int(tok[i, 0]))
            logits, cache = self._decode(cache, tok, jnp.int32(pos))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos += 1
        return outs

    def generate(self, prompt_tokens, max_new: int = 32):
        """Single-request generate (prefill + greedy decode)."""
        cfg = self.cfg
        toks = jnp.asarray(prompt_tokens, jnp.int32)[None, :]
        s = toks.shape[1]
        align = max(cfg.sliding_window or 1, cfg.ssm_chunk if
                    cfg.family in ("ssm", "hybrid") else 1)
        pad_to = -(-s // align) * align if align > 1 else s
        if pad_to != s:  # left-pad-free right alignment: pad with last token
            toks = jnp.pad(toks, ((0, 0), (0, pad_to - s)), mode="edge")
        last_logits, cache = prefill(cfg, self.params, toks)
        cache = pad_cache_to(cache, toks.shape[1], self.max_len)
        # if we padded, the "last" real logit is at position s-1: redo decode
        # alignment by starting from the padded end (greedy continuation).
        pos = toks.shape[1]
        out = []
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        for _ in range(max_new):
            out.append(int(tok[0, 0]))
            logits, cache = self._decode(cache, tok, jnp.int32(pos))
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos += 1
        return out
