"""Serving engine: prefill + batched decode with per-layer-kind caches.

Cache layout mirrors the model's grouped scan structure; sizing is
layer-aware (full-length KV for global attention, W-sized ring buffers for
sliding-window layers, O(1) SSM/conv state for mamba).

Padded-prompt correctness: prompts of unequal length are right-padded to the
window/chunk alignment, but padding never leaks into the output — prefill
gathers each request's logit at ``len(prompt) - 1`` (not the padded end),
the model masks pad positions out of every cache kind (attention validity
mask, sliding-window ring gather, SSM dt-zeroing; see models/), and decode
runs at per-request positions so request i's token t lands at absolute
position ``len(prompt_i) + t``, progressively overwriting the pad slots.
``generate_batch`` is therefore token-identical to unpadded single-request
``generate``.

``ServingEngine.serve`` is the continuous-batching loop: admit a request
into a free batch slot (single-row prefill + cache row insert), decode all
active slots in lockstep (one jit'd step), release on EOS / ``max_new``,
refill from the queue. ``generate``/``generate_batch`` are the static-batch
special case. The division unit is a serving knob: pass ``division=`` to run
every softmax/rmsnorm in the decode path under that mode.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.division_modes import DivisionConfig
from repro.models import forward, make_cache


def prefill(cfg: ModelConfig, params, tokens, *, enc_embeds=None, embeds=None,
            lengths=None):
    """Returns (last_logits (B, V), cache). Seq must respect window/chunk
    alignment (the engine pads requests up to the alignment). With per-request
    ``lengths``, the returned logits are gathered at each request's last REAL
    position ``lengths[i] - 1`` and pad positions are masked out of the
    caches; without, the final position is used (unpadded batch)."""
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = enc_embeds
    if cfg.embed_inputs and not cfg.is_encoder_decoder:
        logits, cache, _ = forward(cfg, params, embeds=embeds, mode="prefill",
                                   lengths=lengths, **kw)
    else:
        logits, cache, _ = forward(cfg, params, tokens=tokens, mode="prefill",
                                   lengths=lengths, **kw)
    if lengths is None:
        return logits[:, -1], cache
    lv = jnp.asarray(lengths, jnp.int32)
    last = jnp.take_along_axis(
        logits, (lv - 1)[:, None, None], axis=1)[:, 0]
    return last, cache


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step. tokens: (B, 1); pos: scalar int32 or per-request (B,)
    vector of absolute positions. -> (logits, cache)."""
    logits, new_cache, _ = forward(cfg, params, tokens=tokens, cache=cache,
                                   pos=pos, mode="decode")
    return logits[:, 0], new_cache


def pad_cache_to(cache, from_len: int, to_len: int, cfg: ModelConfig = None):
    """Grow full-attention KV caches from ``from_len`` to ``to_len`` along the
    sequence axis (axis -3).

    With ``cfg`` the selection is structural: walk the grouped cache beside
    ``cfg.groups()`` and pad only the full-attention ('attn' mixer) K/V
    leaves. Sliding-window rings, SSM state/conv tails, and cross-attention
    K/V are never touched — the legacy shape heuristic (pad anything whose
    ``shape[-3] == from_len``) silently corrupts a ring cache whose window
    equals the prefill length. Without ``cfg`` the heuristic is kept for
    backward compatibility with unambiguous (dense full-attention) callers.
    """
    if to_len < from_len:
        raise ValueError(f"pad_cache_to: to_len {to_len} < from_len {from_len}")
    if to_len == from_len:
        return cache

    def pad(a):
        padw = [(0, 0)] * a.ndim
        padw[-3] = (0, to_len - from_len)
        return jnp.pad(a, padw)

    if cfg is None:
        def maybe(a):
            if a.ndim >= 3 and a.shape[-3] == from_len:
                return pad(a)
            return a
        return jax.tree_util.tree_map(maybe, cache)

    new_groups = []
    for g, gc in zip(cfg.groups(), cache["groups"]):
        layers = []
        for spec, lc in zip(g.period, gc["layers"]):
            lc = dict(lc)
            if spec.mixer == "attn" and "attn" in lc:
                lc["attn"] = {k: pad(v) for k, v in lc["attn"].items()}
            layers.append(lc)
        new_groups.append({"layers": layers})
    return {"groups": new_groups}


def _insert_cache_row(cache, row, slot: int, cfg: ModelConfig):
    """Write single-request cache ``row`` (batch 1) into batch slot ``slot``.

    Leaves of groups with ``repeat > 1`` carry a leading stacked-layers dim,
    so the batch axis is 1 there and 0 elsewhere."""
    new_groups = []
    for g, gc, rc in zip(cfg.groups(), cache["groups"], row["groups"]):
        ax = 1 if g.repeat > 1 else 0

        def ins(a, r, ax=ax):
            start = [0] * a.ndim
            start[ax] = slot
            return jax.lax.dynamic_update_slice(a, r.astype(a.dtype),
                                                tuple(start))

        new_groups.append(jax.tree_util.tree_map(ins, gc, rc))
    return {"groups": new_groups}


@dataclasses.dataclass
class Request:
    tokens: List[int]
    max_new: int = 32
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    """Greedy-decoding engine: static batching (``generate``/``generate_batch``)
    and continuous batching (``serve``) over the smoke/full configs.

    ``division`` swaps the division unit the whole decode path runs on
    (``dataclasses.replace(cfg, division=...)``); ``eos_id`` enables early
    stop on that token."""

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 256,
                 division: Optional[DivisionConfig] = None,
                 eos_id: Optional[int] = None):
        if division is not None:
            cfg = dataclasses.replace(cfg, division=division)
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.eos_id = eos_id
        self._decode = jax.jit(
            lambda c, t, p: decode_step(cfg, params, c, t, p))
        self._prefill_tok = jax.jit(
            lambda t, l: prefill(cfg, params, t, lengths=l))
        self._prefill_emb = jax.jit(
            lambda e, l: prefill(cfg, params, None, embeds=e, lengths=l))
        self._prefill_enc = jax.jit(
            lambda t, enc, l: prefill(cfg, params, t, enc_embeds=enc,
                                      lengths=l))

    # ------------------------------------------------------------- alignment

    @property
    def _align(self) -> int:
        cfg = self.cfg
        a = cfg.sliding_window if cfg.sliding_window else 1
        if cfg.family in ("ssm", "hybrid"):
            a = a * cfg.ssm_chunk // math.gcd(a, cfg.ssm_chunk)
        return a

    def _pad_to(self, s_max: int) -> int:
        return -(-s_max // self._align) * self._align

    def _check_fits(self, s_max: int, max_new: int, pad_to: int):
        need = max(pad_to, s_max + max_new)
        if need > self.max_len:
            raise ValueError(
                f"prompt ({s_max}) + max_new ({max_new}) needs {need} cache "
                f"slots but max_len is {self.max_len}")

    # ----------------------------------------------------------- static batch

    def generate_batch(self, prompts, max_new: int = 32, *, enc_embeds=None,
                       embeds=None):
        """Batched requests of unequal length: right-pad to a common aligned
        length, prefill once (pad positions masked out of every cache kind),
        then decode all slots in lockstep at per-request positions. Output is
        token-identical to per-request unpadded ``generate``.

        VLM (``embed_inputs``) configs take ``embeds``: a list of per-request
        ``(len_i, d_model)`` arrays (decode consumes generated *tokens*).
        Encoder-decoder configs take ``enc_embeds``: ``(B, encoder_seq,
        d_model)``. Returns a list of generated-token lists."""
        cfg = self.cfg
        if cfg.embed_inputs and not cfg.is_encoder_decoder:
            if embeds is None:
                raise ValueError(
                    f"config '{cfg.name}' has embed_inputs=True: pass "
                    "embeds=[...(len_i, d_model) arrays] (prompt tokens have "
                    "no embedding path at prefill)")
            lens = [int(e.shape[0]) for e in embeds]
            B = len(embeds)
        else:
            if not prompts:
                raise ValueError("generate_batch: empty prompt list")
            if any(len(p) == 0 for p in prompts):
                raise ValueError("generate_batch: empty prompt")
            lens = [len(p) for p in prompts]
            B = len(prompts)
        if cfg.is_encoder_decoder and enc_embeds is None:
            raise ValueError(
                f"config '{cfg.name}' is encoder-decoder: pass "
                "enc_embeds=(B, encoder_seq, d_model)")
        s_max = max(lens)
        pad_to = self._pad_to(s_max)
        self._check_fits(s_max, max_new, pad_to)
        lengths = jnp.asarray(lens, jnp.int32)

        if cfg.embed_inputs and not cfg.is_encoder_decoder:
            emb = np.zeros((B, pad_to, cfg.d_model), np.float32)
            for i, e in enumerate(embeds):
                emb[i, :lens[i]] = np.asarray(e, np.float32)
            last_logits, cache = self._prefill_emb(jnp.asarray(emb), lengths)
        else:
            toks = np.zeros((B, pad_to), np.int32)
            for i, p in enumerate(prompts):
                toks[i, :len(p)] = p  # zero right-pad; pads are masked out
            toks = jnp.asarray(toks)
            if cfg.is_encoder_decoder:
                last_logits, cache = self._prefill_enc(
                    toks, jnp.asarray(enc_embeds), lengths)
            else:
                last_logits, cache = self._prefill_tok(toks, lengths)
        cache = pad_cache_to(cache, pad_to, self.max_len, cfg)

        pos_v = lengths  # request i's first generated token sits at len_i
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        outs = [[] for _ in range(B)]
        stopped = [False] * B
        for _ in range(max_new):
            for i in range(B):
                if not stopped[i]:
                    t = int(tok[i, 0])
                    outs[i].append(t)
                    if self.eos_id is not None and t == self.eos_id:
                        stopped[i] = True
            if all(stopped):
                break
            logits, cache = self._decode(cache, tok, pos_v)
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
            pos_v = pos_v + 1
        return outs

    def generate(self, prompt_tokens=None, max_new: int = 32, *,
                 enc_embeds=None, embeds=None):
        """Single-request generate — the batch-of-one case of
        ``generate_batch`` (same padding/masking path, so batched and single
        generation are token-identical)."""
        if enc_embeds is not None and np.ndim(enc_embeds) == 2:
            enc_embeds = jnp.asarray(enc_embeds)[None]
        prompts = None if prompt_tokens is None else [list(prompt_tokens)]
        embs = None if embeds is None else [embeds]
        return self.generate_batch(prompts, max_new, enc_embeds=enc_embeds,
                                   embeds=embs)[0]

    # ------------------------------------------------------ continuous batch

    def serve(self, requests: Sequence[Request], *, slots: int = 2):
        """Continuous batching: admit requests into free batch slots
        (single-row prefill + cache-row insert), decode all active slots in
        lockstep, release each on EOS / its own ``max_new``, refill from the
        queue. Mutates and returns the ``Request`` objects (``out``/``done``).
        """
        cfg = self.cfg
        if cfg.embed_inputs and not cfg.is_encoder_decoder:
            raise ValueError(
                f"serve() prefills token prompts; embed-input config "
                f"'{cfg.name}' must use generate/generate_batch with embeds=")
        if cfg.is_encoder_decoder:
            raise ValueError(
                f"serve() does not carry per-slot encoder state; "
                f"encoder-decoder config '{cfg.name}' must use "
                "generate/generate_batch with enc_embeds=")
        for r in requests:
            if not r.tokens:
                raise ValueError("serve: empty prompt")
            pad_to = self._pad_to(len(r.tokens))
            self._check_fits(len(r.tokens), r.max_new, pad_to)

        B = slots
        cache = make_cache(cfg, B, self.max_len)
        pos_v = np.zeros((B,), np.int32)
        cur = np.zeros((B, 1), np.int32)
        active: List[Optional[Request]] = [None] * B
        queue = list(requests)

        def admit(slot: int, req: Request):
            nonlocal cache
            s = len(req.tokens)
            pad_to = self._pad_to(s)
            toks = np.zeros((1, pad_to), np.int32)
            toks[0, :s] = req.tokens
            last, row = self._prefill_tok(jnp.asarray(toks),
                                          jnp.asarray([s], jnp.int32))
            row = pad_cache_to(row, pad_to, self.max_len, cfg)
            cache = _insert_cache_row(cache, row, slot, cfg)
            cur[slot, 0] = int(jnp.argmax(last[0]))
            pos_v[slot] = s
            active[slot] = req

        while True:
            for i in range(B):
                if active[i] is None and queue:
                    admit(i, queue.pop(0))
            if not any(a is not None for a in active):
                break
            # record this step's token; release finished slots before decode
            for i in range(B):
                req = active[i]
                if req is None:
                    continue
                t = int(cur[i, 0])
                req.out.append(t)
                if len(req.out) >= req.max_new or (
                        self.eos_id is not None and t == self.eos_id):
                    req.done = True
                    active[i] = None
                    pos_v[i] = 0  # idle slot decodes garbage at pos 0;
                    # the row is fully overwritten on the next admit
            if not any(a is not None for a in active) and not queue:
                break
            logits, cache = self._decode(cache, jnp.asarray(cur),
                                         jnp.asarray(pos_v))
            cur = np.asarray(jnp.argmax(logits, axis=-1))[:, None].astype(np.int32)
            pos_v = pos_v + 1
        return list(requests)
