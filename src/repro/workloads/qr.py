"""QR decomposition via Givens rotations on the division unit.

QR is the second application the source paper names, and the Givens-rotation
unit of arXiv:2010.12376 (Hormigo & Muñoz — see PAPERS.md) is exactly a
hardware consumer of divide/rsqrt: zeroing entry (i, j) needs the rotation
coefficients

    r = sqrt(a^2 + b^2),   c = a / r,   s = b / r

with a = R[j, j], b = R[i, j]. Both evaluation strategies are offered, and
both route through :mod:`repro.core.division_modes`:

  * ``via="div"``   — r by square root, then the two quotients through
                      ``division_modes.div`` (two divides per rotation, the
                      source paper's unit on its headline op);
  * ``via="rsqrt"`` — one ``division_modes.rsqrt`` of a^2 + b^2, then two
                      multiplies (the Givens-unit formulation: division-free
                      at the cost of the rsqrt datapath).

The decomposition sweeps column by column, zeroing below-diagonal entries
with plane rotations applied to full rows (vectorized over N), accumulated
into an explicit Q. It is mode-agnostic: ``qr_givens(a, cfg=EXACT)`` is the
XLA-exact twin for accuracy deltas, and
:func:`repro.eval.workload_metrics.qr_residuals` turns (Q, R, A) into the
orthogonality / reconstruction / triangularity numbers recorded in
``BENCH_div.json``.
"""
from __future__ import annotations

import numpy as np

from repro.core import division_modes as dm

__all__ = ["givens_coeffs", "qr_givens", "qr_givens_batched",
           "qr_givens_sharded"]


def givens_coeffs(a, b, cfg: dm.DivisionConfig = dm.TAYLOR,
                  via: str = "div"):
    """Rotation coefficients (c, s) zeroing b against a; c^2 + s^2 = 1.

    The (a, b) = (0, 0) corner returns the identity rotation (c, s) = (1, 0)
    — the edge lanes of the division unit (0/0 -> nan, rsqrt(0) -> inf) are
    masked here, mirroring the special-value handling a hardware Givens unit
    wraps around its divider.

    The operands are pre-scaled by an exact power of two so a^2 + b^2 never
    under/overflows f32 while a and b are normal (the textbook safe-Givens
    scaling; (c, s) is 0-homogeneous in (a, b), so the scale cancels — a
    power of two keeps the scaling rounding-free, and the exponent shift is
    not a mantissa divide, so no division bypasses the unit).
    """
    import jax.numpy as jnp

    m = jnp.maximum(jnp.abs(a), jnp.abs(b))
    # floor's zero gradient makes inv a constant under autodiff — exactly
    # right, since (c, s) does not depend on the scale at all.
    e = jnp.clip(jnp.floor(jnp.log2(jnp.where(m > 0, m, 1.0))), -126.0, 126.0)
    inv = jnp.exp2(-e).astype(a.dtype)
    an, bn = a * inv, b * inv
    t = an * an + bn * bn                   # in [1, 8) whenever (a, b) != 0
    if via == "rsqrt":
        inv_r = dm.rsqrt(t, cfg)
        c, s = an * inv_r, bn * inv_r
    elif via == "div":
        r = jnp.sqrt(t)
        c, s = dm.div(an, r, cfg), dm.div(bn, r, cfg)
    else:
        raise ValueError(f"via must be 'div' or 'rsqrt', got {via!r}")
    safe = m > 0
    c = jnp.where(safe, c, jnp.ones_like(c))
    s = jnp.where(safe, s, jnp.zeros_like(s))
    return c, s


def _rotation_schedule(m: int, n: int):
    """Static (j, i) pairs: for each column j, zero rows j+1..m-1."""
    jj, ii = [], []
    for j in range(min(m - 1, n)):
        for i in range(j + 1, m):
            jj.append(j)
            ii.append(i)
    return np.asarray(jj, np.int32), np.asarray(ii, np.int32)


def qr_givens(a, cfg: dm.DivisionConfig = dm.TAYLOR, *, via: str = "div"):
    """Full QR of an (M, N) matrix, M >= 1: returns (Q, R) with A = Q @ R.

    Q is (M, M) orthogonal (a product of plane rotations), R is (M, N) with
    below-diagonal entries annihilated to the working precision — they are
    returned as computed (order-ulp residues, not hard zeros) so the
    delivered accuracy of the division mode is visible in the triangularity
    residual rather than masked by a ``triu``.

    The rotation sequence is data-independent (column-major, top-down), so
    the whole decomposition is one ``fori_loop`` over a static schedule:
    each step computes (c, s) through the configured division mode and
    applies the rotation to full rows of R and Q^T (vectorized over N).
    """
    import jax
    import jax.numpy as jnp

    a = jnp.asarray(a)
    if a.ndim != 2:
        raise ValueError(f"qr_givens expects a 2D matrix, got shape {a.shape}")
    m, n = a.shape
    r = a
    qt = jnp.eye(m, dtype=a.dtype)
    jj, ii = _rotation_schedule(m, n)
    if len(jj) == 0:
        return qt.T, r
    jj, ii = jnp.asarray(jj), jnp.asarray(ii)

    def body(k, carry):
        qt, r = carry
        j, i = jj[k], ii[k]
        rj, ri = r[j], r[i]
        c, s = givens_coeffs(rj[j], ri[j], cfg, via)
        r = r.at[j].set(c * rj + s * ri).at[i].set(c * ri - s * rj)
        qj, qi = qt[j], qt[i]
        qt = qt.at[j].set(c * qj + s * qi).at[i].set(c * qi - s * qj)
        return qt, r

    qt, r = jax.lax.fori_loop(0, len(jj), body, (qt, r))
    return qt.T, r


def qr_givens_batched(a, cfg: dm.DivisionConfig = dm.TAYLOR, *,
                      via: str = "div"):
    """QR of a batch of matrices: (..., M, N) -> (Q (..., M, M), R (..., M, N)).

    vmap over the flattened leading dims — the rotation schedule is static,
    so every batch member shares one trace and the per-rotation divides
    vectorize across the batch.
    """
    import jax
    import jax.numpy as jnp

    a = jnp.asarray(a)
    if a.ndim < 2:
        raise ValueError(f"qr_givens_batched expects (..., M, N), got {a.shape}")
    if a.ndim == 2:
        return qr_givens(a, cfg, via=via)
    lead = a.shape[:-2]
    a3 = a.reshape((-1,) + a.shape[-2:])
    q3, r3 = jax.vmap(lambda mat: qr_givens(mat, cfg, via=via))(a3)
    return (q3.reshape(lead + q3.shape[-2:]),
            r3.reshape(lead + r3.shape[-2:]))


def qr_givens_sharded(a, cfg: dm.DivisionConfig = dm.TAYLOR, *,
                      via: str = "div"):
    """Batched Givens QR with the batch dim sharded over the active mesh.

    ``a`` is (B, M, N); the batch shards over the largest divisible prefix of
    ('pod','data') (``rules.batch_partition``) and each device decomposes its
    resident matrices with :func:`qr_givens_batched`. The rotations are
    entirely intra-matrix, so there is nothing to reduce across the mesh —
    sharded QR is bit-identical to the batched single-device run. Division
    sites run under ``rules.suspend_mesh()`` (the body is already inside a
    shard_map). Falls back to :func:`qr_givens_batched` when no mesh is
    active or no batch-axis prefix divides B.
    """
    import jax.numpy as jnp
    from repro.sharding import rules as shr

    a = jnp.asarray(a)
    if a.ndim != 3:
        raise ValueError(f"qr_givens_sharded wants (B, M, N), got {a.shape}")
    mesh = shr.active_mesh()
    axes = shr.batch_partition(mesh, a.shape[0]) if mesh is not None else ()
    n_shards = 1
    for ax in axes:
        n_shards *= mesh.shape[ax]
    if n_shards <= 1:
        return qr_givens_batched(a, cfg, via=via)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(al):
        with shr.suspend_mesh():
            return qr_givens_batched(al, cfg, via=via)

    spec = P(axes, None, None)
    return shard_map(body, mesh=mesh, in_specs=(spec,),
                     out_specs=(spec, spec), check_rep=False)(a)
