"""Division-consumer workloads: the paper's "non-traditional applications".

The source paper's pitch is that a fast, programmable-accuracy divider
unlocks workloads that are traditionally restructured to *avoid* division —
it names K-Means clustering and QR decomposition explicitly. This package
is those workloads, built so that **every divide/rsqrt routes through
``repro.core.division_modes``**: one ``DivisionConfig`` knob swaps the
whole workload between the XLA-native divider and any of the paper-derived
units (Taylor paper/factored, Goldschmidt, their fused Pallas kernels, ILM).

  * ``kmeans`` — batched Lloyd iterations; the assignment distances and the
    centroid update are the division sites (`kmeans.kmeans`).
  * ``qr``     — QR decomposition via Givens rotations; the rotation
    coefficients c = a/r, s = b/r are the division sites, with a choice of
    divide-based or rsqrt-based coefficient evaluation (`qr.qr_givens`) —
    the consumption pattern of the Givens-rotation unit of arXiv:2010.12376
    (Hormigo & Muñoz, see PAPERS.md).

Because the algorithms are mode-agnostic, the XLA-exact twin of any run is
the same function with ``cfg=EXACT`` — accuracy deltas per mode are measured
by ``repro.eval.workload_metrics`` and recorded by ``benchmarks/run.py``
(``--only workloads``) into ``BENCH_div.json``.
"""
from . import kmeans, qr  # noqa: F401

__all__ = ["kmeans", "qr"]
