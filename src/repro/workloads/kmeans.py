"""Batched K-Means (Lloyd) with every divide routed through the division unit.

K-Means is one of the two applications the source paper names as unlocked by
a cheap divider. Lloyd's algorithm has two division sites per iteration, and
both go through :mod:`repro.core.division_modes` here:

  1. **Assignment distances** — points are assigned by *mean* squared
     distance ``||x - c||^2 / D`` (the per-dimension normalization keeps the
     distance scale D-independent); the ``1/D`` is a batched divide over the
     whole (N, K) distance plane, which the Pallas modes stream through the
     tiled fused kernel.
  2. **Centroid update** — ``c_k = sum(x_i in k) / count_k``, a batched
     (K, D) / (K, 1) divide. Empty clusters keep their previous centroid
     (the divide's inf/nan lanes are masked out, as hardware FTZ would).

The inertia (mean within-cluster squared distance) is itself divided through
the unit, so the reported objective carries the mode's error signature too.

Everything is mode-agnostic: ``kmeans(x, k, cfg=EXACT)`` is the XLA-exact
twin of ``kmeans(x, k, cfg=DivisionConfig(mode="taylor"))`` on identical
inits, and :func:`repro.eval.workload_metrics.relative_delta` turns the two
inertias into the workload-level accuracy number recorded in
``BENCH_div.json``.

Supports leading batch dimensions: ``x`` of shape (..., N, D) clusters each
batch member independently (one shared init per call).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core import division_modes as dm

__all__ = ["KMeansResult", "kmeans", "kmeans_sharded", "lloyd_step",
           "pairwise_mean_sqdist", "make_blobs"]


@dataclasses.dataclass(frozen=True)
class KMeansResult:
    """Outcome of a Lloyd run.

    centroids:     (..., K, D) final centroids.
    assignments:   (..., N) int32 cluster index per point (final centroids).
    inertia:       (...,) mean min squared distance under the final centroids.
    inertia_trace: (n_iters, ...) inertia before each update step — the
                   convergence curve, one entry per Lloyd iteration.
    """

    centroids: "object"
    assignments: "object"
    inertia: "object"
    inertia_trace: "object"


def pairwise_mean_sqdist(x, c, cfg: dm.DivisionConfig = dm.TAYLOR):
    """Mean squared distance plane ||x_n - c_k||^2 / D, shape (..., N, K).

    Expanded as x.x - 2 x.c + c.c (one einsum feeds the MXU on TPU); the
    1/D normalizer is the assignment-side division site and goes through
    ``division_modes.div`` — for the Pallas modes the whole (N, K) plane
    streams through the tiled fused divide kernel.
    """
    import jax.numpy as jnp

    x2 = jnp.sum(x * x, axis=-1)[..., :, None]
    c2 = jnp.sum(c * c, axis=-1)[..., None, :]
    xc = jnp.einsum("...nd,...kd->...nk", x, c)
    d2 = jnp.maximum(x2 - 2.0 * xc + c2, 0.0)
    return dm.div(d2, jnp.asarray(x.shape[-1], x.dtype), cfg)


def _assign_and_inertia(x, c, cfg: dm.DivisionConfig):
    """Assignment + mean inertia under fixed centroids (no update)."""
    import jax.numpy as jnp

    d2 = pairwise_mean_sqdist(x, c, cfg)
    assign = jnp.argmin(d2, axis=-1)
    n_pts = jnp.asarray(x.shape[-2], x.dtype)
    inertia = dm.div(jnp.sum(jnp.min(d2, axis=-1), axis=-1), n_pts, cfg)
    return d2, assign, inertia


# Canonical accumulation blocking for the (N, K) x (N, D) centroid sums.
# Both the single-device and the sharded path reduce the same 8 row-major
# block partials in the same left-to-right order, so sharding cannot move
# the centroid sums by more than per-block matmul scheduling noise (the
# sums are f32; one global einsum vs a psum tree would differ by several
# ulps at N ~ 10^6 — see docs/numerics.md).
_SUM_BLOCKS = 8


def _block_cluster_sums(onehot, x, n_blocks: int):
    """(n_blocks, K, D) per-cluster sums over row-major row blocks."""
    import jax.numpy as jnp

    parts = [jnp.einsum("nk,nd->kd", o, b)
             for o, b in zip(jnp.split(onehot, n_blocks, axis=0),
                             jnp.split(x, n_blocks, axis=0))]
    return jnp.stack(parts)


def _ordered_block_sum(stacked):
    """Left-to-right sum over the leading axis — one fixed reduction order."""
    out = stacked[0]
    for i in range(1, stacked.shape[0]):
        out = out + stacked[i]
    return out


def _cluster_sums(onehot, x):
    """Per-cluster coordinate sums, (..., K, D), canonical order when 2D."""
    import jax.numpy as jnp

    if x.ndim == 2 and x.shape[0] % _SUM_BLOCKS == 0:
        return _ordered_block_sum(_block_cluster_sums(onehot, x, _SUM_BLOCKS))
    return jnp.einsum("...nk,...nd->...kd", onehot, x)


def lloyd_step(x, c, cfg: dm.DivisionConfig = dm.TAYLOR):
    """One Lloyd iteration: assign, update centroids, measure inertia.

    Returns ``(new_centroids, assignments, inertia)`` where inertia is
    measured *before* the update (the objective the assignment minimized).
    """
    import jax
    import jax.numpy as jnp

    k = c.shape[-2]
    d2, assign, inertia = _assign_and_inertia(x, c, cfg)
    onehot = jax.nn.one_hot(assign, k, dtype=x.dtype)        # (..., N, K)
    counts = jnp.sum(onehot, axis=-2)                        # (..., K)
    sums = _cluster_sums(onehot, x)                          # (..., K, D)
    # Empty clusters: divide by max(count, 1) — not by the raw count — so
    # the 0/0 lane never exists even in exact mode, whose d(a/b) = 1/b
    # cotangent would turn into 0 * inf = nan under the where mask below
    # (the approximate modes survive via attach_grad's finite-lane masking,
    # exact mode has no such guard). The masked lanes keep the previous
    # centroid — the workload-level analogue of the FTZ edge contract.
    occupied = counts[..., :, None] > 0
    new_c = dm.div(sums, jnp.maximum(counts, 1)[..., :, None], cfg)
    new_c = jnp.where(occupied, new_c, c)
    return new_c, assign, inertia


def kmeans(x, k: Optional[int] = None, *, cfg: dm.DivisionConfig = dm.TAYLOR,
           n_iters: int = 10, init=None, key=None) -> KMeansResult:
    """Run ``n_iters`` Lloyd iterations of K-Means on ``x`` (..., N, D).

    ``init`` (shape (..., K, D)) pins the starting centroids — pass the same
    init to two modes to measure the division unit's effect in isolation.
    Without it, ``k`` distinct points are drawn with ``key``
    (default PRNGKey(0)); the draw is shared across leading batch dims.
    """
    import jax
    import jax.numpy as jnp

    x = jnp.asarray(x)
    if init is None:
        if k is None:
            raise ValueError("pass k or an explicit init")
        if key is None:
            key = jax.random.PRNGKey(0)
        idx = jax.random.choice(key, x.shape[-2], (k,), replace=False)
        init = jnp.take(x, idx, axis=-2)
    else:
        init = jnp.asarray(init, x.dtype)
        if k is not None and k != init.shape[-2]:
            raise ValueError(f"k={k} != init.shape[-2]={init.shape[-2]}")
    # One centroid set per batch member (a shared init broadcasts up front so
    # the scan carry keeps a fixed shape).
    init = jnp.broadcast_to(init, x.shape[:-2] + init.shape[-2:])

    def step(c, _):
        new_c, _, inertia = lloyd_step(x, c, cfg)
        return new_c, inertia

    centroids, trace = jax.lax.scan(step, init, None, length=n_iters)
    # Final assignment/inertia under the converged centroids — evaluation
    # only, no discarded centroid update.
    _, assign, inertia = _assign_and_inertia(x, centroids, cfg)
    return KMeansResult(centroids=centroids, assignments=assign,
                        inertia=inertia, inertia_trace=trace)


def kmeans_sharded(x, k: Optional[int] = None, *,
                   cfg: dm.DivisionConfig = dm.TAYLOR, n_iters: int = 10,
                   init=None, key=None) -> KMeansResult:
    """Data-parallel Lloyd over the active mesh: production-scale K-Means.

    ``x`` must be (N, D); points shard over the batch axes (the largest
    divisible prefix of ('pod','data'), see ``rules.batch_partition``) and
    centroids replicate. Each iteration runs the assignment on resident
    points only, then ``psum``s the per-cluster sums *and* counts across the
    mesh **before** the centroid divide — so the division unit consumes
    globally-reduced operands and empty-cluster masking sees global counts
    (a locally-empty cluster is not an empty cluster). The per-point
    assignment distances are elementwise in N, so assignments match the
    unsharded run bit-for-bit; the centroid sums are reduced tree-wise by
    ``psum`` rather than in one row-major einsum, which can move the last
    bit (see docs/numerics.md) — hence the <= 1 int ulp centroid gate in
    tests/test_sharded_kernels.py.

    Division sites inside the body run under ``rules.suspend_mesh()`` so the
    mesh-aware kernel dispatch never nests a second shard_map. Falls back to
    plain :func:`kmeans` when no mesh is active or no batch-axis prefix
    divides N.
    """
    import jax
    import jax.numpy as jnp
    from repro.sharding import rules as shr

    x = jnp.asarray(x)
    if x.ndim != 2:
        raise ValueError(f"kmeans_sharded wants (N, D) points, got {x.shape}")
    mesh = shr.active_mesh()
    axes = shr.batch_partition(mesh, x.shape[0]) if mesh is not None else ()
    n_shards = 1
    for ax in axes:
        n_shards *= mesh.shape[ax]
    if n_shards <= 1:
        return kmeans(x, k, cfg=cfg, n_iters=n_iters, init=init, key=key)

    if init is None:
        if k is None:
            raise ValueError("pass k or an explicit init")
        if key is None:
            key = jax.random.PRNGKey(0)
        idx = jax.random.choice(key, x.shape[0], (k,), replace=False)
        init = jnp.take(x, idx, axis=0)
    else:
        init = jnp.asarray(init, x.dtype)
        if k is not None and k != init.shape[-2]:
            raise ValueError(f"k={k} != init.shape[-2]={init.shape[-2]}")
    kk = init.shape[-2]
    n_total = jnp.asarray(x.shape[0], x.dtype)
    # When the canonical _SUM_BLOCKS blocking aligns with the shard layout,
    # each shard contributes whole blocks and the partials are combined in
    # the same left-to-right order as the single-device _cluster_sums —
    # that is what makes the <= 1 ulp centroid gate hold at 10^6 points.
    blocked = (x.shape[0] % _SUM_BLOCKS == 0
               and _SUM_BLOCKS % n_shards == 0)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def body(xl, c0):
        # xl: (N / n_shards, D) resident points; c0: replicated (K, D).
        with shr.suspend_mesh():
            def step(c, _):
                d2 = pairwise_mean_sqdist(xl, c, cfg)
                assign = jnp.argmin(d2, axis=-1)
                onehot = jax.nn.one_hot(assign, kk, dtype=xl.dtype)
                # Global reduction BEFORE the divide: the unit sees the
                # whole cluster's sum/count, not a shard's slice of it.
                # Counts are integer-valued f32 (exact up to 2^24), so the
                # psum order cannot move them; the sums are reduced in the
                # canonical block order when the layout allows (an
                # order-fixed psum: gather the block partials in shard
                # order, then one left-to-right sum on every device).
                counts = jax.lax.psum(jnp.sum(onehot, axis=-2), axes)
                if blocked:
                    parts = _block_cluster_sums(
                        onehot, xl, _SUM_BLOCKS // n_shards)
                    parts = jax.lax.all_gather(parts, axes, axis=0,
                                               tiled=True)
                    sums = _ordered_block_sum(parts)
                else:
                    sums = jax.lax.psum(
                        jnp.einsum("nk,nd->kd", onehot, xl), axes)
                inertia = dm.div(
                    jax.lax.psum(jnp.sum(jnp.min(d2, axis=-1)), axes),
                    n_total, cfg)
                occupied = counts[:, None] > 0
                new_c = dm.div(sums, jnp.maximum(counts, 1)[:, None], cfg)
                new_c = jnp.where(occupied, new_c, c)
                return new_c, inertia

            centroids, trace = jax.lax.scan(step, c0, None, length=n_iters)
            d2 = pairwise_mean_sqdist(xl, centroids, cfg)
            assign = jnp.argmin(d2, axis=-1)
            inertia = dm.div(
                jax.lax.psum(jnp.sum(jnp.min(d2, axis=-1)), axes),
                n_total, cfg)
        return centroids, assign, inertia, trace

    pts = P(axes, None)
    run = shard_map(
        body, mesh=mesh, in_specs=(pts, P()),
        # Everything but the assignments is psum-replicated across the mesh.
        out_specs=(P(), P(axes), P(), P()), check_rep=False)
    centroids, assign, inertia, trace = run(x, init)
    return KMeansResult(centroids=centroids, assignments=assign,
                        inertia=inertia, inertia_trace=trace)


def make_blobs(key, n: int, d: int, k: int, *, spread: float = 0.15,
               dtype=None):
    """Gaussian blob mixture for tests/benchmarks: (n, d) points, k centers.

    Centers are drawn uniform in [-1, 1]^d and points jittered around them
    with stddev ``spread`` — separated enough that all modes should agree on
    the clustering, close enough that near-boundary points exercise the
    divide's low bits.
    """
    import jax
    import jax.numpy as jnp

    dtype = dtype or jnp.float32
    kc, kp, kj = jax.random.split(key, 3)
    centers = jax.random.uniform(kc, (k, d), dtype, -1.0, 1.0)
    which = jax.random.randint(kp, (n,), 0, k)
    noise = spread * jax.random.normal(kj, (n, d), dtype)
    return jnp.take(centers, which, axis=0) + noise
