"""AdamW with the update division routed through the paper's unit.

The Adam step  m_hat / (sqrt(v_hat) + eps)  is a per-parameter divide — on a
hardware design like the paper's this is exactly the workload the unit
accelerates. ``division`` selects exact | taylor; bias-correction reciprocals
(scalars) stay exact.

State dtype is configurable (f32 default; bf16 for the 398B config) and the
tree mirrors params, so optimizer state shards with the same PartitionSpecs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import division_modes as dm


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    division: dm.DivisionConfig = dm.EXACT


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree_util.tree_map(zeros, params),
                      v=jax.tree_util.tree_map(zeros, params))


def abstract_state(params_abstract, cfg: AdamWConfig) -> AdamWState:
    dt = jnp.dtype(cfg.state_dtype)
    z = lambda p: jax.ShapeDtypeStruct(p.shape, dt)
    return AdamWState(step=jax.ShapeDtypeStruct((), jnp.int32),
                      m=jax.tree_util.tree_map(z, params_abstract),
                      v=jax.tree_util.tree_map(z, params_abstract))


def _global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(grads, state: AdamWState, params, cfg: AdamWConfig,
           lr_scale: jax.Array | float = 1.0):
    """Returns (new_params, new_state)."""
    step = state.step + 1
    gnorm = _global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32) * clip
        mf = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        vf = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mhat = mf / c1
        vhat = vf / c2
        denom = jnp.sqrt(vhat) + cfg.eps
        if cfg.division.mode == "exact":
            delta = mhat / denom
        else:
            delta = mhat * dm.recip(denom, cfg.division)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (delta + cfg.weight_decay * pf)
        return (pf.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype))

    out = jax.tree_util.tree_map(upd, grads, state.m, state.v, params)
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
