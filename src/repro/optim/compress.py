"""Int8 error-feedback gradient compression for the cross-pod (DCN) axis.

At 512+ chips the pod-crossing all-reduce rides data-center network, ~10x
slower per byte than ICI. Quantizing the pod-reduction operand to int8 with a
pod-shared per-tensor scale cuts DCN bytes 4x (vs f32) / 2x (vs bf16); the
residual (error feedback, Karimireddy et al. 2019) carries into the next step
so quantization noise is compensated over time and convergence is preserved
(validated in tests/test_optim.py on a real loss curve).

Protocol per tensor (inside a pjit/shard_map body with a named 'pod' axis):
  1. compensate:  g' = g + err
  2. share scale: s = pmax_pod(max|g'|) / 127     (scalar collective, ~free)
  3. quantize:    q = round(g'/s) in int8
  4. reduce:      acc = psum_pod(q as int16)      (int16 accumulators are safe
                  up to 256 pods; the wire format models int8 + switch-side
                  accumulation — roofline counts 1 byte/element)
  5. dequantize:  mean = acc * s / n_pods;  err' = g' - q*s
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_tree(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(grads, err_tree, axis_name: str):
    """Cross-pod mean of grads with int8 error-feedback compression.

    Returns (mean_tree_f32, new_err_tree). Must run where ``axis_name`` is a
    manual/named axis (shard_map) or inside jit with mesh axis semantics.
    """
    n = jax.lax.psum(1, axis_name)

    def leaf(g, err):
        gf = g.astype(jnp.float32) + err
        local_max = jnp.max(jnp.abs(gf))
        scale = jnp.maximum(jax.lax.pmax(local_max, axis_name), 1e-30) / 127.0
        q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
        acc = jax.lax.psum(q.astype(jnp.int16), axis_name)
        mean = acc.astype(jnp.float32) * scale / n
        new_err = gf - q.astype(jnp.float32) * scale
        return mean, new_err

    out = jax.tree_util.tree_map(leaf, grads, err_tree)
    is_pair = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], tuple)
    mean = jax.tree_util.tree_map(lambda t: t[0], out, is_leaf=is_pair)
    new_err = jax.tree_util.tree_map(lambda t: t[1], out, is_leaf=is_pair)
    return mean, new_err


def quantize_roundtrip(g, err):
    """Single-host test hook: quantize + dequantize with error feedback."""
    gf = g.astype(jnp.float32) + err
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    return deq, gf - deq
