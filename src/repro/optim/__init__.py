from . import adamw, compress
from .adamw import AdamWConfig

__all__ = ["adamw", "compress", "AdamWConfig"]
