"""Logical-axis -> mesh-axis resolution (t5x-style rules).

A parameter's logical axes (e.g. ('embed', 'heads', 'head_dim')) resolve to a
PartitionSpec through the arch's rules dict. Two safety drops keep every spec
valid by construction:
  * divisibility drop — a dim not divisible by its mesh axis size falls back
    to replicated (this is how GQA with kv_heads < model-axis size degrades to
    Megatron-style replicated KV);
  * duplicate drop — a mesh axis already consumed by an earlier dim of the
    same param is not reused (e.g. Jamba experts->data + embed->data).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, rules_for
from repro.models import params as params_lib


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             rules: Dict[str, Optional[str]], mesh: Mesh,
             drops: Optional[list] = None) -> P:
    """Resolve a param's logical axes to a PartitionSpec.

    ``drops``, when passed, collects one record per *silent fallback*: a dim
    whose rule named a mesh axis that could not be honored (duplicate use,
    axis missing from the mesh, or size not divisible). Dims whose rule is
    None are intended replication, not drops. The dry-run threads these into
    its per-cell report so a replicated 8B-param tensor is a named line, not
    a surprise OOM (see launch/dryrun.py).
    """
    parts = []
    used = set()
    for dim, (size, ax) in enumerate(zip(shape, axes)):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            parts.append(None)
            continue
        if mesh_ax in used:
            reason = "duplicate"
        elif mesh_ax not in mesh.shape:
            reason = "missing-axis"
        elif size % mesh.shape[mesh_ax] != 0:
            reason = "indivisible"
        else:
            parts.append(mesh_ax)
            used.add(mesh_ax)
            continue
        if drops is not None:
            drops.append({
                "dim": dim, "logical_axis": ax, "mesh_axis": mesh_ax,
                "dim_size": int(size),
                "mesh_axis_size": int(mesh.shape.get(mesh_ax, 0)),
                "reason": reason,
            })
        parts.append(None)
    return P(*parts)


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    """Pytree of NamedSharding matching abstract_params(cfg)."""
    rules = rules_for(cfg)
    abstract = params_lib.abstract_params(cfg)
    axes = params_lib.logical_axes(cfg)
    return jax.tree_util.tree_map(
        lambda a, ax: NamedSharding(mesh, spec_for(a.shape, ax, rules, mesh)),
        abstract, axes)


def param_fallbacks(cfg: ModelConfig, mesh) -> list:
    """Every silent sharding drop across the model's params, as report rows.

    One entry per (param, dim) whose rule-named mesh axis was dropped, with
    the param's path, shape, and full (replicated) byte size attached.
    ``mesh`` only needs a ``.shape`` mapping, so production mesh shapes can
    be audited without 512 placeholder devices.
    """
    import numpy as np

    rules = rules_for(cfg)
    abstract = params_lib.abstract_params(cfg)
    axes = params_lib.logical_axes(cfg)
    entries: list = []

    def visit(path, a, ax):
        drops: list = []
        spec_for(a.shape, ax, rules, mesh, drops=drops)
        for d in drops:
            entries.append({
                "param": jax.tree_util.keystr(path),
                "shape": list(a.shape),
                "bytes": int(np.prod(a.shape)) * a.dtype.itemsize,
                **d,
            })
        return None

    jax.tree_util.tree_map_with_path(visit, abstract, axes)
    return entries


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch dim: ('pod','data') when pod exists."""
    return tuple(ax for ax in ("pod", "data") if ax in mesh.shape)


def batch_partition(mesh: Mesh, batch_size: Optional[int]) -> Tuple[str, ...]:
    """Largest prefix of ('pod','data') whose device product divides the batch.

    The all-or-nothing predecessor replicated the whole batch whenever the
    *combined* ('pod','data') count didn't divide it — e.g. batch=16 on a
    pod=2 x data=16 mesh fell back to fully replicated even though the pod
    axis alone divides 16. Shrinking from the right instead shards over
    ('pod',) there; batch_size=None means shapes are unconstrained and the
    full prefix is used.
    """
    ba = batch_axes(mesh)
    if batch_size is None:
        return ba
    while ba:
        n = 1
        for ax in ba:
            n *= mesh.shape[ax]
        if batch_size % n == 0:
            return ba
        ba = ba[:-1]
    return ()


def data_spec(mesh, ndim: int, *, batch_dim: int = 0,
              seq_dim: Optional[int] = None, seq_axis: Optional[str] = None,
              batch_size: Optional[int] = None) -> P:
    """The PartitionSpec behind :func:`data_sharding` (mesh needs only
    ``.shape``, so rule logic is testable against production mesh shapes)."""
    parts: list = [None] * ndim
    ba = batch_partition(mesh, batch_size)
    if ba:
        parts[batch_dim] = ba if len(ba) > 1 else ba[0]
    if seq_dim is not None and seq_axis is not None and seq_axis in mesh.shape:
        parts[seq_dim] = seq_axis
    return P(*parts)


def data_sharding(mesh: Mesh, ndim: int, *, batch_dim: int = 0,
                  seq_dim: Optional[int] = None, seq_axis: Optional[str] = None,
                  batch_size: Optional[int] = None) -> NamedSharding:
    """Input sharding: batch over the largest divisible prefix of
    ('pod','data'); optional sequence sharding (long-context decode shards
    the KV-cache seq dim instead of batch=1)."""
    return NamedSharding(mesh, data_spec(
        mesh, ndim, batch_dim=batch_dim, seq_dim=seq_dim, seq_axis=seq_axis,
        batch_size=batch_size))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------- activations
# Model code applies *partial* sharding constraints (P.UNCONSTRAINED elsewhere)
# at points where GSPMD's propagation is known to go wrong (GQA head-repeat:
# without a constraint the partitioner all-reduces full score tensors). The
# active mesh is registered by the launcher; without one, constraints no-op so
# single-device tests/examples run unchanged.

import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = getattr(_ACTIVE, "mesh", None)
    _ACTIVE.mesh = mesh
    try:
        yield
    finally:
        _ACTIVE.mesh = prev


@contextlib.contextmanager
def suspend_mesh():
    """Hide the active mesh for a scope.

    The mesh-aware kernel dispatch (kernels/ops.py) wraps launches in
    shard_map when a mesh is registered; code already *inside* a shard_map
    body (workloads.kmeans_sharded, qr_givens_sharded) runs its division
    sites under this so the dispatch never tries to nest a second shard_map
    over the same mesh. Works under tracing: shard_map traces its body
    synchronously, inside this context's dynamic extent.
    """
    prev = getattr(_ACTIVE, "mesh", None)
    _ACTIVE.mesh = None
    try:
        yield
    finally:
        _ACTIVE.mesh = prev


def active_mesh() -> Optional[Mesh]:
    return getattr(_ACTIVE, "mesh", None)


def shard_dim(x, dim: int, axis: str = "model"):
    """Constrain one dim of x to a mesh axis; UNCONSTRAINED elsewhere.
    No-op when no mesh is active, axis missing, or dim not divisible."""
    mesh = active_mesh()
    if mesh is None or axis not in mesh.shape:
        return x
    if dim < 0:
        dim += x.ndim
    if x.shape[dim] % mesh.shape[axis] != 0:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    spec[dim] = axis
    return jax.lax.with_sharding_constraint(x, P(*spec))
