"""Logical-axis -> mesh-axis resolution (t5x-style rules).

A parameter's logical axes (e.g. ('embed', 'heads', 'head_dim')) resolve to a
PartitionSpec through the arch's rules dict. Two safety drops keep every spec
valid by construction:
  * divisibility drop — a dim not divisible by its mesh axis size falls back
    to replicated (this is how GQA with kv_heads < model-axis size degrades to
    Megatron-style replicated KV);
  * duplicate drop — a mesh axis already consumed by an earlier dim of the
    same param is not reused (e.g. Jamba experts->data + embed->data).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, rules_for
from repro.models import params as params_lib


def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             rules: Dict[str, Optional[str]], mesh: Mesh) -> P:
    parts = []
    used = set()
    for size, ax in zip(shape, axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if (mesh_ax is None or mesh_ax in used
                or mesh_ax not in mesh.shape
                or size % mesh.shape[mesh_ax] != 0):
            parts.append(None)
            continue
        parts.append(mesh_ax)
        used.add(mesh_ax)
    return P(*parts)


def param_shardings(cfg: ModelConfig, mesh: Mesh):
    """Pytree of NamedSharding matching abstract_params(cfg)."""
    rules = rules_for(cfg)
    abstract = params_lib.abstract_params(cfg)
    axes = params_lib.logical_axes(cfg)
    return jax.tree_util.tree_map(
        lambda a, ax: NamedSharding(mesh, spec_for(a.shape, ax, rules, mesh)),
        abstract, axes)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry the batch dim: ('pod','data') when pod exists."""
    return tuple(ax for ax in ("pod", "data") if ax in mesh.shape)


def data_sharding(mesh: Mesh, ndim: int, *, batch_dim: int = 0,
                  seq_dim: Optional[int] = None, seq_axis: Optional[str] = None,
                  batch_size: Optional[int] = None) -> NamedSharding:
    """Input sharding: batch over ('pod','data'); optional sequence sharding
    (long-context decode shards the KV-cache seq dim instead of batch=1)."""
    parts: list = [None] * ndim
    ba = batch_axes(mesh)
    n_batch_devices = 1
    for ax in ba:
        n_batch_devices *= mesh.shape[ax]
    if batch_size is None or batch_size % n_batch_devices == 0:
        parts[batch_dim] = ba if len(ba) > 1 else (ba[0] if ba else None)
    if seq_dim is not None and seq_axis is not None and seq_axis in mesh.shape:
        parts[seq_dim] = seq_axis
    return NamedSharding(mesh, P(*parts))


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# --------------------------------------------------------------- activations
# Model code applies *partial* sharding constraints (P.UNCONSTRAINED elsewhere)
# at points where GSPMD's propagation is known to go wrong (GQA head-repeat:
# without a constraint the partitioner all-reduces full score tensors). The
# active mesh is registered by the launcher; without one, constraints no-op so
# single-device tests/examples run unchanged.

import contextlib
import threading

_ACTIVE = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    prev = getattr(_ACTIVE, "mesh", None)
    _ACTIVE.mesh = mesh
    try:
        yield
    finally:
        _ACTIVE.mesh = prev


def active_mesh() -> Optional[Mesh]:
    return getattr(_ACTIVE, "mesh", None)


def shard_dim(x, dim: int, axis: str = "model"):
    """Constrain one dim of x to a mesh axis; UNCONSTRAINED elsewhere.
    No-op when no mesh is active, axis missing, or dim not divisible."""
    mesh = active_mesh()
    if mesh is None or axis not in mesh.shape:
        return x
    if dim < 0:
        dim += x.ndim
    if x.shape[dim] % mesh.shape[axis] != 0:
        return x
    spec = [P.UNCONSTRAINED] * x.ndim
    spec[dim] = axis
    return jax.lax.with_sharding_constraint(x, P(*spec))
