"""Sharding scaling driver: one process, one device count, one JSON line.

Times the mesh-aware division-unit paths on whatever devices this process
sees (the caller sets ``XLA_FLAGS=--xla_force_host_platform_device_count=N``
before launch — jax locks the device count at first init, which is why this
is a subprocess driver and not a benchmark function):

  * tiled fused divide through ``kernels.ops.tsdiv_divide`` on data-sharded
    (rows, cols) operands (interpret-mode Pallas off-TPU);
  * data-parallel K-Means (``workloads.kmeans_sharded``, mode=taylor —
    compiled XLA) at --points scale.

At device_count=1 both fall back to their single-device paths, so running
this at 1 and N devices yields the scaling pair recorded in BENCH_div.json
(benchmarks/run.py bench_sharding). The last stdout line is the JSON result.

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.sharding.scaling --points 1000000
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional, Sequence


def _time_us(fn, *args, reps: int, warmup: int = 1):
    out = None
    for _ in range(warmup):
        out = fn(*args)
    for o in out if isinstance(out, (tuple, list)) else (out,):
        if hasattr(o, "block_until_ready"):
            o.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    for o in out if isinstance(out, (tuple, list)) else (out,):
        if hasattr(o, "block_until_ready"):
            o.block_until_ready()
    return (time.perf_counter() - t0) / reps * 1e6


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--points", type=int, default=1_000_000)
    ap.add_argument("--dim", type=int, default=8)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--iters", type=int, default=4)
    ap.add_argument("--rows", type=int, default=2048)
    ap.add_argument("--cols", type=int, default=384)
    ap.add_argument("--reps", type=int, default=3)
    args = ap.parse_args(argv)

    import jax
    import jax.numpy as jnp

    from repro.core import division_modes as dm
    from repro.kernels import ops
    from repro.launch.mesh import make_host_mesh
    from repro.sharding import rules as shr
    from repro.workloads import kmeans as km

    n_dev = jax.device_count()
    mesh = make_host_mesh()

    a = jax.random.uniform(jax.random.PRNGKey(0), (args.rows, args.cols),
                           jnp.float32, 0.1, 10.0)
    b = jax.random.uniform(jax.random.PRNGKey(1), (args.rows, args.cols),
                           jnp.float32, 0.1, 10.0)
    sh2 = shr.data_sharding(mesh, 2, batch_size=args.rows)
    a_s, b_s = jax.device_put(a, sh2), jax.device_put(b, sh2)
    with shr.use_mesh(mesh):
        f_div = jax.jit(lambda u, v: ops.tsdiv_divide(u, v))
        us_div = _time_us(f_div, a_s, b_s, reps=args.reps)

    x = km.make_blobs(jax.random.PRNGKey(2), args.points, args.dim, args.k)
    init = jnp.take(x, jnp.arange(args.k) * (args.points // args.k), axis=0)
    x_s = jax.device_put(x, shr.data_sharding(mesh, 2,
                                              batch_size=args.points))
    cfg = dm.DivisionConfig(mode="taylor")
    with shr.use_mesh(mesh):
        def run_kmeans(xx, ii):
            res = km.kmeans_sharded(xx, cfg=cfg, n_iters=args.iters, init=ii)
            return res.centroids, res.assignments, res.inertia

        f_km = jax.jit(run_kmeans)
        us_km = _time_us(f_km, x_s, init, reps=args.reps)
        inertia = float(f_km(x_s, init)[2])

    print(json.dumps({
        "devices": n_dev,
        "mesh": dict(mesh.shape),
        "tiled_divide_us": us_div,
        "tiled_divide_shape": [args.rows, args.cols],
        "kmeans_us": us_km,
        "kmeans": {"points": args.points, "dim": args.dim, "k": args.k,
                   "iters": args.iters, "inertia": inertia},
    }))
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
