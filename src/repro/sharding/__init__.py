from .rules import (active_mesh, batch_axes, batch_partition, data_sharding,
                    data_spec, param_fallbacks, param_shardings, replicated,
                    spec_for, suspend_mesh, use_mesh)

__all__ = ["active_mesh", "batch_axes", "batch_partition", "data_sharding",
           "data_spec", "param_fallbacks", "param_shardings", "replicated",
           "spec_for", "suspend_mesh", "use_mesh"]
