from .rules import batch_axes, data_sharding, param_shardings, replicated, spec_for

__all__ = ["batch_axes", "data_sharding", "param_shardings", "replicated", "spec_for"]
