"""Launchers: production-mesh dry-run, roofline analysis, train/serve drivers.

NOTE: do not import .dryrun from here — it sets XLA_FLAGS at import time and
must only be imported as __main__ (or deliberately, first, by tooling).
"""
from . import mesh, roofline

__all__ = ["mesh", "roofline"]
