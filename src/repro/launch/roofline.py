"""Roofline analysis from compiled dry-run artifacts (TPU v5e model).

Three terms, all in seconds-per-step on the target hardware:
  compute    = HLO_FLOPs_per_device / PEAK_FLOPS
  memory     = HLO_bytes_per_device / HBM_BW
  collective = sum over collective ops of wire_bytes(op) / link_BW
               (ICI and DCN accounted separately; DCN = groups spanning pods)

``cost_analysis()`` provides per-device flops / bytes-accessed. Collective
bytes are parsed from the compiled HLO text: for each all-reduce /
all-gather / reduce-scatter / all-to-all / collective-permute we take the
result-shape bytes and apply the standard ring-algorithm wire factor over the
replica-group size g:
  all-reduce      2*(g-1)/g     all-gather / reduce-scatter   (g-1)/g
  all-to-all      (g-1)/g       collective-permute            1
Hardware constants: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI,
~25 GB/s/host DCN (assumption recorded in EXPERIMENTS.md).
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link
DCN_BW = 25e9           # bytes/s per host (cross-pod)

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"^\s*(?:%\S+\s*=\s*)?"
    r"(?P<types>\(?[a-z0-9\[\],{}\s/_*]*\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"(?P<dt>pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[(?P<dims>[0-9,]*)\]")

_GROUPS_RE = re.compile(r"replica_groups=\{(.*?)\}\}?|replica_groups=\[")


def _shape_bytes(types_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(types_str):
        dims = m.group("dims")
        n = 1
        if dims.strip():
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group("dt")]
    return total


def _crosses_pod(groups, pod_size: Optional[int]) -> bool:
    if not pod_size:
        return False
    for ids in groups:
        pods = {i // pod_size for i in ids}
        if len(pods) > 1:
            return True
    return False


def _group_info(line: str, n_devices: int, pod_size: Optional[int]
                ) -> Tuple[int, bool]:
    """Returns (group_size, crosses_pod). Handles both explicit
    ``replica_groups={{0,1},{2,3}}`` and iota
    ``replica_groups=[R,G]<=[d0,d1,..]T(p..)`` forms exactly."""
    m = re.search(r"replica_groups=\{\{(.*?)\}\}", line)
    if m:
        groups = []
        for grp in m.group(1).split("},{"):
            groups.append([int(x) for x in grp.split(",") if x.strip()])
        g = max(len(x) for x in groups)
        return g, _crosses_pod(groups, pod_size)
    mi = re.search(
        r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?", line)
    if mi:
        import numpy as _np

        r, g = int(mi.group(1)), int(mi.group(2))
        dims = [int(x) for x in mi.group(3).split(",")]
        ids = _np.arange(int(_np.prod(dims))).reshape(dims)
        if mi.group(4):
            perm = [int(x) for x in mi.group(4).split(",")]
            ids = ids.transpose(perm)
        groups = ids.reshape(r, g).tolist()
        return g, _crosses_pod(groups, pod_size)
    return n_devices, pod_size is not None and n_devices > pod_size


_WIRE_FACTOR = {
    "all-reduce": lambda g: 2.0 * (g - 1) / g,
    "all-gather": lambda g: (g - 1) / g,
    "reduce-scatter": lambda g: (g - 1) / g,
    "all-to-all": lambda g: (g - 1) / g,
    "collective-permute": lambda g: 1.0,
}


def parse_collectives(hlo_text: str, n_devices: int,
                      pod_size: Optional[int] = None) -> Dict:
    """Sum wire bytes per device over all collective ops in the HLO.

    Two tallies: raw (as compiled for CPU) and TPU-corrected. The XLA CPU
    backend has no bf16 compute, so it upcasts bf16 partial sums to f32
    before all-reducing (operands named ``%convert...``); on TPU those
    reductions ride the wire in bf16 — the corrected tally halves them.
    (Verified: the StableHLO keeps bf16; the f32 appears only post-CPU-
    partitioning, always behind a convert fusion.)"""
    ici_bytes = 0.0
    dcn_bytes = 0.0
    ici_tpu = 0.0
    dcn_tpu = 0.0
    ops: List[Dict] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.match(line)
        if not m:
            continue
        if "-done" in line.split("(")[0]:
            continue  # async pair: count the -start only
        op = m.group("op").lower()
        nbytes = _shape_bytes(m.group("types"))
        if nbytes == 0:
            continue
        g, crosses = _group_info(line, n_devices, pod_size)
        wire = _WIRE_FACTOR[op](max(g, 1)) * nbytes
        # CPU-upcast detection: f32 reduction fed by a convert fusion
        upcast = (op in ("all-reduce", "reduce-scatter")
                  and "f32" in m.group("types") and "%convert" in line)
        wire_tpu = wire * (0.5 if upcast else 1.0)
        if crosses:
            dcn_bytes += wire
            dcn_tpu += wire_tpu
        else:
            ici_bytes += wire
            ici_tpu += wire_tpu
        ops.append({"op": op, "bytes": nbytes, "group": g,
                    "wire_bytes": wire, "wire_bytes_tpu": wire_tpu,
                    "cross_pod": crosses, "cpu_upcast": upcast})
    return {"ici_bytes": ici_bytes, "dcn_bytes": dcn_bytes,
            "ici_bytes_tpu": ici_tpu, "dcn_bytes_tpu": dcn_tpu, "ops": ops}


def elementwise_hbm_bytes(n_elements: int, *, n_operands: int = 2,
                          n_results: int = 1, dtype_bytes: int = 4,
                          n_devices: int = 1) -> float:
    """Per-device HBM traffic model for an elementwise kernel.

    A fused divide/rsqrt kernel streams each operand in and each result out
    exactly once; sharded over ``n_devices`` every device touches its
    resident 1/n slice. The sharded-kernel tests compare this against
    ``cost_analysis()['bytes accessed']`` to pin that shard_map actually
    divided the traffic instead of all-gathering it.
    """
    return (n_operands + n_results) * n_elements * dtype_bytes / n_devices


def allreduce_wire_bytes(n_elements: int, group_size: int,
                         dtype_bytes: int = 4) -> float:
    """Ring all-reduce wire bytes per device: 2*(g-1)/g * payload.

    The analytic twin of what :func:`parse_collectives` tallies from HLO —
    used to validate that e.g. the K-Means psum-of-sums/psum-of-counts
    collective traffic matches the (K*D + K) payload model.
    """
    return _WIRE_FACTOR["all-reduce"](max(group_size, 1)) * \
        n_elements * dtype_bytes


@dataclasses.dataclass
class Roofline:
    flops: float                # per device
    bytes_accessed: float       # per device
    ici_bytes: float            # TPU-corrected wire bytes (bf16 reductions)
    dcn_bytes: float
    model_flops: float          # 6ND (train) / 2ND (inference), per device
    ici_bytes_raw: float = 0.0  # as-compiled-for-CPU tally (f32 upcasts)
    dcn_bytes_raw: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.ici_bytes / ICI_BW + self.dcn_bytes / DCN_BW

    @property
    def t_collective_raw(self) -> float:
        return self.ici_bytes_raw / ICI_BW + self.dcn_bytes_raw / DCN_BW

    @property
    def bound(self) -> str:
        ts = {"compute": self.t_compute, "memory": self.t_memory,
              "collective": self.t_collective}
        return max(ts, key=ts.get)

    @property
    def t_step(self) -> float:
        """Perfect-overlap model: step time = max of the three terms."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mfu(self) -> float:
        """Model-flops utilization at the roofline step time."""
        if self.t_step == 0:
            return 0.0
        return self.model_flops / PEAK_FLOPS / self.t_step

    @property
    def flops_efficiency(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs: fraction of compiled compute that is
        'useful' (remat recompute and padding waste lower this)."""
        return self.model_flops / self.flops if self.flops else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops": self.flops, "bytes_accessed": self.bytes_accessed,
            "ici_bytes": self.ici_bytes, "dcn_bytes": self.dcn_bytes,
            "ici_bytes_raw": self.ici_bytes_raw,
            "dcn_bytes_raw": self.dcn_bytes_raw,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute, "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "t_collective_raw": self.t_collective_raw,
            "t_step": self.t_step,
            "bound": self.bound, "mfu": self.mfu,
            "flops_efficiency": self.flops_efficiency,
        }


def model_flops_per_device(n_active_params: int, tokens_global: int,
                           n_devices: int, kind: str) -> float:
    """6ND for training, 2ND for inference forward passes."""
    c = 6.0 if kind == "train" else 2.0
    return c * n_active_params * tokens_global / n_devices
