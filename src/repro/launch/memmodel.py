"""Analytical HBM-traffic model for the memory roofline term.

XLA's ``cost_analysis()['bytes accessed']`` sums operand bytes of every HLO op
with no TPU fusion model — attention score tensors alone inflate it by an
order of magnitude (on TPU they live in VMEM inside a fused kernel). The
roofline memory term instead comes from this explicit per-component model of
what actually crosses HBM on a v5e, per device per step:

  weights      local shard read per microbatch (x2 for backward), plus
               gather-write+read for FSDP ('data'-sharded) leaves
  grads        f32 accumulator read+write per microbatch
  optimizer    param rw + m/v rw + grad read, once per step
  activations  per-layer tensor traffic (residuals, projections, FFN/MoE
               buffers, SSD chunk tensors); train multiplies by 4
               (fwd 1 + bwd 2 + remat recompute 1)
  scores       attention probability matrices — counted ONLY when
               fused_attention=False (the baseline; a flash-style fused
               kernel keeps them in VMEM, which is hillclimb lever #1)
  kv cache     decode: full local cache read + one-token write
  logits       f32 logits write/read for CE loss (+ grad) / sampling

The HLO bytes-accessed number is still recorded per cell as an upper bound.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig, rules_for
from repro.models.params import ParamSpec, model_specs
from repro.sharding.rules import spec_for


def _axis_size(mesh, name):
    return mesh.shape.get(name, 1)


def _param_traffic(cfg: ModelConfig, mesh, n_micro: int, kind: str) -> Dict:
    """Weight-read / grad / optimizer traffic from the actual shardings."""
    rules = rules_for(cfg)
    specs = model_specs(cfg)
    leaves = [p for p in _iter_specs(specs)]
    w_read = 0.0      # per microbatch
    count_local = 0.0
    pbytes = np.dtype(cfg.param_dtype).itemsize
    sbytes = np.dtype(cfg.opt_state_dtype).itemsize
    for p in leaves:
        s = spec_for(p.shape, p.axes, rules, mesh)
        shard_factor = 1
        data_sharded = False
        for part in s:
            if part is None:
                continue
            for ax in (part if isinstance(part, tuple) else (part,)):
                shard_factor *= _axis_size(mesh, ax)
                if ax in ("data", "pod"):
                    data_sharded = True
        n_local = int(np.prod(p.shape)) / shard_factor
        count_local += n_local
        lb = n_local * (np.dtype(p.dtype).itemsize if p.dtype else pbytes)
        w_read += lb
        if data_sharded:
            # FSDP: all-gather writes + reads the model-sharded-only tensor
            w_read += 2 * lb * (shard_factor // _prod_model(mesh, s))
    if kind == "train":
        weights = w_read * n_micro * 2          # fwd + bwd weight reads
        grads = count_local * 4 * 2 * n_micro   # f32 accumulator rw
        opt = count_local * (2 * pbytes + 4 * sbytes + 4)
    else:
        weights = w_read
        grads = 0.0
        opt = 0.0
    return {"weights": weights, "grads": grads, "opt": opt}


def _prod_model(mesh, spec):
    f = 1
    for part in spec:
        if part is None:
            continue
        for ax in (part if isinstance(part, tuple) else (part,)):
            if ax == "model":
                f *= _axis_size(mesh, ax)
    return f


def _iter_specs(tree):
    if isinstance(tree, ParamSpec):
        yield tree
    elif isinstance(tree, dict):
        for v in tree.values():
            yield from _iter_specs(v)
    elif isinstance(tree, (list, tuple)):
        for v in tree:
            yield from _iter_specs(v)


def hbm_traffic(cfg: ModelConfig, shape: ShapeConfig, mesh, *, n_micro: int = 1,
                fused_attention: bool = False) -> Dict:
    """Per-device, per-step HBM bytes, with component breakdown."""
    M = _axis_size(mesh, "model")
    D = _axis_size(mesh, "data") * _axis_size(mesh, "pod")
    B, S = shape.global_batch, shape.seq_len
    kind = "train" if shape.kind == "train" else "inference"
    act_mult = 4.0 if kind == "train" else 1.0   # fwd + 2 bwd + 1 remat
    bf2 = 2.0

    batch_local = max(1, B // D) if B >= D else B  # batch=1: replicated
    if shape.kind == "decode":
        t = batch_local * 1                       # tokens/device/step
        s_kv = S                                  # cache length attended
    else:
        t = batch_local * S / max(1, n_micro) if kind == "train" \
            else batch_local * S
        s_kv = S
    d = cfg.d_model

    pt = _param_traffic(cfg, mesh, n_micro, kind)

    acts = 0.0
    scores = 0.0
    cache = 0.0
    def _loc(n, m):
        """Local share: n/m when shardable, else replicated (full n)."""
        return n / m if (n and n % m == 0) else n

    for spec in cfg.layer_specs():
        # residual stream + norms: ~8 x (t, d) bf16 accesses
        a = 8 * t * d * bf2
        if spec.mixer == "mamba":
            din_loc = _loc(cfg.d_inner, M)
            h_loc = _loc(cfg.ssm_heads, M)
            q = min(cfg.ssm_chunk, S)
            a += 6 * t * din_loc * bf2 + 4 * t * cfg.ssm_state * bf2
            # SSD intra-chunk decay/score tensors: (nc, q, q) per head local
            if shape.kind != "decode":
                scores_l = 4 * h_loc * t * q * 4.0
                scores += scores_l if not fused_attention else 0.0
            else:
                cache += h_loc * cfg.ssm_head_dim * cfg.ssm_state * 4 * 2 \
                    * batch_local
            a += 2 * t * din_loc * bf2  # gated norm + out proj activations
        else:
            h_loc = _loc(cfg.n_heads, M)
            kv_loc = _loc(cfg.n_kv_heads, M)
            hd = cfg.head_dim
            a += (2 * t * h_loc * hd + 4 * t * kv_loc * hd) * bf2
            window = cfg.sliding_window if spec.mixer == "swa" else 0
            s_att = min(window, s_kv) * 2 if window else s_kv
            if shape.kind == "decode":
                L = min(window, S) if window else S
                if cfg.sharding_rules.get("__kv_seq_shard__"):
                    L = L / M  # flash-decoding: cache seq sharded over model
                cache += 2 * batch_local * L * kv_loc * hd * bf2  # k+v read
                scores += (0 if fused_attention else
                           4 * batch_local * h_loc * L * 4.0)
            else:
                scores += (0 if fused_attention else
                           4 * h_loc * t * s_att * 4.0)
        if spec.ffn == "dense":
            f_loc = _loc(cfg.dense_ff, M)
            a += (4 * t * f_loc + 2 * t * d) * bf2
        elif spec.ffn == "moe":
            E, k = cfg.n_experts, cfg.experts_per_tok
            f_loc = _loc(cfg.d_ff_expert, M)
            # dispatched tokens per device ~ t*k (capacity ~1.25)
            a += 2 * t * k * d * bf2 * 1.25          # dispatch + combine
            a += 4 * t * k * f_loc * bf2 * 1.25      # expert MLP acts
            a += t * E * 4.0                         # router logits f32
            if cfg.n_shared_experts:
                a += 4 * t * cfg.n_shared_experts * f_loc * bf2
        acts += a
    # t was per-microbatch for train: scale to the full step
    acts *= act_mult * (n_micro if kind == "train" else 1)
    scores *= act_mult * (n_micro if kind == "train" else 1)

    v_loc = _loc(cfg.vocab, M)
    logits = (3 if kind == "train" else 1) * t * v_loc * 4.0
    if kind == "train":
        logits *= n_micro

    total = (pt["weights"] + pt["grads"] + pt["opt"] + acts + scores + cache
             + logits)
    return {
        "weights_bytes": pt["weights"], "grads_bytes": pt["grads"],
        "opt_bytes": pt["opt"], "activation_bytes": acts,
        "score_bytes": scores, "cache_bytes": cache, "logits_bytes": logits,
        "fused_attention": fused_attention,
        "total_bytes": total,
    }
