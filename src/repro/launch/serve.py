"""Serving launcher: prefill + batched greedy decode on the host.

  PYTHONPATH=src python -m repro.launch.serve --arch paper_fpdiv --smoke \
      --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_fpdiv")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.serving import ServingEngine

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    engine = ServingEngine(cfg, params, max_len=args.prompt_len + args.max_new + 64)
    prompt = list(range(1, args.prompt_len + 1))
    out = engine.generate(prompt, max_new=args.max_new)
    print(f"prompt({len(prompt)} toks) -> generated {len(out)} tokens: {out}")


if __name__ == "__main__":
    main()
