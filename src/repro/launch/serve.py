"""Serving launcher: prefill + greedy decode on the host, division unit as a knob.

  PYTHONPATH=src python -m repro.launch.serve --arch paper_fpdiv --smoke \
      --prompt-len 32 --max-new 16 --batch 4 --division-mode goldschmidt

``--batch 1`` runs the single-request path; ``--batch N`` runs the batched
path over N unequal-length prompts (exercising the padded-prompt masking).
``--division-mode``/``--n-iters``/``--schedule`` swap the division unit the
whole decode path runs on. Prints generated tokens plus prefill latency and
decode throughput.
"""
from __future__ import annotations

import argparse
import dataclasses
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_fpdiv")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--division-mode", default=None,
                    choices=["exact", "taylor", "taylor_pallas", "goldschmidt",
                             "goldschmidt_pallas", "ilm"],
                    help="division unit for every softmax/rmsnorm in the "
                         "decode path (default: the config's own mode)")
    ap.add_argument("--n-iters", type=int, default=None,
                    help="Taylor/Goldschmidt iteration count")
    ap.add_argument("--schedule", default=None, choices=["paper", "factored"],
                    help="Taylor evaluation schedule")
    args = ap.parse_args()

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config, get_smoke_config
    from repro.models import init_params
    from repro.serving import ServingEngine

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    division = None
    if args.division_mode or args.n_iters or args.schedule:
        repl = {}
        if args.division_mode:
            repl["mode"] = args.division_mode
        if args.n_iters:
            repl["n_iters"] = args.n_iters
        if args.schedule:
            repl["schedule"] = args.schedule
        division = dataclasses.replace(cfg.division, **repl)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    engine = ServingEngine(cfg, params, division=division,
                           max_len=args.prompt_len + args.max_new + 64)
    print(f"[serve] arch={cfg.name} division={engine.cfg.division.mode} "
          f"n_iters={engine.cfg.division.n_iters} "
          f"schedule={engine.cfg.division.schedule} batch={args.batch}")

    if args.batch > 1:
        # unequal-length prompts exercise the padded-prompt masking path
        prompts = [list(range(1, max(2, args.prompt_len + 1 - 3 * i)))
                   for i in range(args.batch)]
        t0 = time.perf_counter()
        outs = engine.generate_batch(prompts, max_new=args.max_new)
        dt = time.perf_counter() - t0
        for p, o in zip(prompts, outs):
            print(f"prompt({len(p)} toks) -> generated {len(o)} tokens: {o}")
        n_tok = sum(len(o) for o in outs)
    else:
        prompt = list(range(1, args.prompt_len + 1))
        t0 = time.perf_counter()
        out = engine.generate(prompt, max_new=args.max_new)
        dt = time.perf_counter() - t0
        print(f"prompt({len(prompt)} toks) -> generated {len(out)} tokens: {out}")
        n_tok = len(out)
    print(f"[serve] {n_tok} tokens in {dt:.2f}s "
          f"(incl. compile) = {n_tok / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
