"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: 512 placeholder
CPU devices host the production mesh; every train/prefill/decode program is
jit-lowered against ShapeDtypeStruct stand-ins (zero allocation — Jamba-398B
costs nothing), compiled through GSPMD, and its memory_analysis /
cost_analysis / collective schedule recorded for the roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3_8b \
      --shape train_4k --mesh single --out experiments/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
# The VERY FIRST lines, before any jax import: the dry-run (and only the
# dry-run) needs 512 placeholder devices; jax locks device count at first init.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 " + \
    os.environ.get("XLA_FLAGS", "")

import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCH_IDS, LM_SHAPES, get_config, rules_for,
                           shapes_for)
from repro.configs.base import ModelConfig, ShapeConfig
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.models import abstract_params, forward, make_cache
from repro.models.params import active_param_count
from repro.optim import adamw
from repro.sharding import rules as shr
from repro.train import step as train_step_lib


# ------------------------------------------------------------- input specs

def input_specs(cfg: ModelConfig, shape: ShapeConfig):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind == "train":
        if cfg.embed_inputs and not cfg.is_encoder_decoder:
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.is_encoder_decoder:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    elif shape.kind == "prefill":
        if cfg.embed_inputs and not cfg.is_encoder_decoder:
            specs["embeds"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        else:
            specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if cfg.is_encoder_decoder:
            specs["enc_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return specs


def _batch_sharding_tree(cfg, shape, specs, mesh):
    out = {}
    for k, v in specs.items():
        out[k] = shr.data_sharding(mesh, v.ndim, batch_size=shape.global_batch)
    return out


def _cache_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh):
    """Shard caches: batch over data axes when divisible; otherwise (long
    context, batch=1) shard the KV seq dim over 'data'. Heads/state shard
    over 'model' when divisible."""
    B = shape.global_batch
    # Largest divisible prefix of ('pod','data') — not all-or-nothing: a
    # batch divisible by 'pod' alone still shards over it (rules.py).
    ba = shr.batch_partition(mesh, B)
    batch_ok = bool(ba)
    model_n = mesh.shape["model"]

    cache = make_cache(cfg, B, shape.seq_len, abstract=True)

    def spec_for_leaf(path_names, a):
        nd = a.ndim
        parts = [None] * nd
        name = path_names[-1]
        # Trailing ranks (leading dims, if any, are 'layers' scan stacking):
        #   k/v/ck/cv: (B, L, kv, hd)   state: (B, h, p, n)   conv_*: (B, w-1, c)
        trail = 3 if name.startswith("conv") else 4
        bdim = nd - trail
        if batch_ok and a.shape[bdim] == B:
            parts[bdim] = ba if len(ba) > 1 else ba[0]
        kv_seq = cfg.sharding_rules.get("__kv_seq_shard__")
        if name in ("k", "v", "ck", "cv"):
            if kv_seq and a.shape[nd - 3] % mesh.shape.get(kv_seq, 1) == 0:
                # flash-decoding layout: cache sequence over the model axis
                parts[nd - 3] = kv_seq
            elif "data" not in ba and "data" in mesh.shape \
                    and a.shape[nd - 3] % mesh.shape["data"] == 0:
                parts[nd - 3] = "data"  # sequence-parallel cache (batch=1)
            if parts[nd - 3] != "model" and a.shape[nd - 2] % model_n == 0:
                parts[nd - 2] = "model"
        elif name == "state":
            if a.shape[nd - 3] % model_n == 0:
                parts[nd - 3] = "model"  # ssm heads
        elif name.startswith("conv"):
            if a.shape[nd - 1] % model_n == 0:
                parts[nd - 1] = "model"
        return NamedSharding(mesh, P(*parts))

    def walk(tree, path=()):
        if isinstance(tree, dict):
            return {k: walk(v, path + (k,)) for k, v in tree.items()}
        if isinstance(tree, list):
            return [walk(v, path + (str(i),)) for i, v in enumerate(tree)]
        return spec_for_leaf([p for p in path if not p.isdigit()] or ("?",), tree)

    return cache, walk(cache)


# --------------------------------------------------------------- cell runner

def _lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, *,
                n_micro: int, global_batch: int):
    """Build and lower the cell's program. Returns (lowered, kind)."""
    params_abs = abstract_params(cfg)
    pshard = shr.param_shardings(cfg, mesh)
    specs = input_specs(cfg, dataclasses_replace_batch(shape, global_batch))
    bshard = {k: shr.data_sharding(mesh, v.ndim, batch_size=global_batch)
              for k, v in specs.items()}
    opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype,
                                division=cfg.division)

    if shape.kind == "train":
        state_abs = train_step_lib.abstract_state(cfg, params_abs, opt_cfg)
        state_shard = train_step_lib.TrainState(
            params=pshard,
            opt=adamw.AdamWState(step=NamedSharding(mesh, P()),
                                 m=pshard, v=pshard),
            step=NamedSharding(mesh, P()))

        def fn(state, batch):
            new_state, metrics = train_step_lib.train_step(
                cfg, opt_cfg, state, batch, n_micro=n_micro)
            return new_state, metrics["loss"]

        lowered = jax.jit(
            fn, in_shardings=(state_shard, bshard),
            out_shardings=(state_shard, NamedSharding(mesh, P())),
            donate_argnums=(0,),
        ).lower(state_abs, specs)
        return lowered, "train"

    shape_b = dataclasses_replace_batch(shape, global_batch)
    if shape.kind == "prefill":
        cache_abs, cache_shard = _cache_shardings(cfg, shape_b, mesh)

        def fn(params, batch):
            logits, cache, _ = forward(cfg, params, mode="prefill", **batch)
            return logits[:, -1], cache

        logits_shard = shr.data_sharding(mesh, 2, batch_size=global_batch)
        lowered = jax.jit(
            fn, in_shardings=(pshard, bshard),
            out_shardings=(logits_shard, cache_shard),
        ).lower(params_abs, specs)
        return lowered, "inference"

    cache_abs, cache_shard = _cache_shardings(cfg, shape_b, mesh)

    def fn(params, cache, tokens):
        logits, new_cache, _ = forward(
            cfg, params, tokens=tokens, cache=cache,
            pos=jnp.int32(shape.seq_len - 1), mode="decode")
        return logits[:, 0], new_cache

    logits_shard = shr.data_sharding(mesh, 2, batch_size=global_batch)
    lowered = jax.jit(
        fn, in_shardings=(pshard, cache_shard, bshard["tokens"]),
        out_shardings=(logits_shard, cache_shard),
        donate_argnums=(1,),
    ).lower(params_abs, cache_abs, specs["tokens"])
    return lowered, "inference"


def dataclasses_replace_batch(shape: ShapeConfig, global_batch: int):
    import dataclasses as dc

    return dc.replace(shape, global_batch=global_batch)


def _probe_measure(cfg, shape, mesh, global_batch, n_dev, pod_size):
    """Compile one small probe and extract {flops, bytes, ici, dcn, ops}."""
    lowered, _ = _lower_cell(cfg, shape, mesh, n_micro=1,
                             global_batch=global_batch)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):   # jax <= 0.4.x: one dict per program
        cost = cost[0] if cost else {}
    colls = rl.parse_collectives(compiled.as_text(), n_dev, pod_size)
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "ici": colls["ici_bytes_tpu"],
        "dcn": colls["dcn_bytes_tpu"],
        "ici_raw": colls["ici_bytes"],
        "dcn_raw": colls["dcn_bytes"],
        "ops": colls["ops"],
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool, *,
             variant: str = "base", skip_probe: bool = False):
    """Per cell:
      1. REAL program (scans rolled, full depth/microbatches): compile proof
         + memory_analysis. This is the runnability deliverable.
      2. COST PROBES: XLA's cost_analysis counts a while-loop body ONCE
         regardless of trip count (verified empirically), so per-step cost is
         reconstructed affinely: lower tiny stacks with group repeats
         (1,..,1) and (1,..,2,..,1), chunk-scans unrolled, one microbatch;
         cost = fixed + sum_g (R_g) * marginal_g, then x n_micro.
         Probes are small (1-2 periods) => fast compiles at full fidelity of
         per-layer HLO (remat, collectives, MoE dispatch all included).
    """
    import dataclasses as dc

    cfg = get_config(arch)
    cfg, model_axis = apply_variant(cfg, variant)
    shape = LM_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod, model=model_axis)
    n_dev = mesh.devices.size
    pod_size = (n_dev // mesh.shape["pod"]) if "pod" in mesh.shape else None

    n_batch = 1
    for ax in shr.batch_axes(mesh):
        n_batch *= mesh.shape[ax]
    if shape.kind == "train":
        per_dev_batch = max(1, shape.global_batch // n_batch)
        n_micro = max(1, per_dev_batch // cfg.train_microbatch_size)
    else:
        n_micro = 1

    base_groups = cfg.groups()
    n_groups = len(base_groups)

    with mesh, shr.use_mesh(mesh):
        # --- 1. real program: the runnability proof + memory analysis
        t0 = time.time()
        lowered, kind = _lower_cell(cfg, shape, mesh, n_micro=n_micro,
                                    global_batch=shape.global_batch)
        compiled = lowered.compile()
        t_compile = time.time() - t0
        mem = compiled.memory_analysis()

        # --- 2. affine cost probes
        t0 = time.time()
        KEYS = ("flops", "bytes", "ici", "dcn", "ici_raw", "dcn_raw")
        agg = {k: 0.0 for k in KEYS}
        ops_sample = []
        if not skip_probe:
            probe_batch = (shape.global_batch // n_micro
                           if shape.kind == "train" else shape.global_batch)
            ones = tuple(1 for _ in range(n_groups))
            pcfg = dc.replace(cfg, scan_unroll=True,
                              group_repeat_override=ones)
            p0 = _probe_measure(pcfg, shape, mesh, probe_batch, n_dev, pod_size)
            ops_sample = p0["ops"]
            marginals = []
            for gi in range(n_groups):
                if base_groups[gi].repeat == 1:
                    marginals.append(None)  # fixed part already covers it
                    continue
                rep = tuple(2 if i == gi else 1 for i in range(n_groups))
                pcfg_g = dc.replace(cfg, scan_unroll=True,
                                    group_repeat_override=rep)
                pg = _probe_measure(pcfg_g, shape, mesh, probe_batch, n_dev,
                                    pod_size)
                marginals.append({k: pg[k] - p0[k] for k in KEYS})
            for k in agg:
                total = p0[k]
                for gi, m in enumerate(marginals):
                    if m is not None:
                        total += (base_groups[gi].repeat - 1) * m[k]
                agg[k] = total * n_micro
        t_probe = time.time() - t0

    n_active = active_param_count(cfg)
    tokens_global = (shape.global_batch * shape.seq_len
                     if shape.kind != "decode" else shape.global_batch)
    model_flops = rl.model_flops_per_device(n_active, tokens_global, n_dev, kind)

    from repro.launch import memmodel
    mm = memmodel.hbm_traffic(cfg, shape, mesh, n_micro=n_micro,
                              fused_attention=cfg.use_flash_kernel)

    roof = rl.Roofline(
        flops=agg["flops"],
        bytes_accessed=mm["total_bytes"],
        ici_bytes=agg["ici"],
        dcn_bytes=agg["dcn"],
        ici_bytes_raw=agg["ici_raw"],
        dcn_bytes_raw=agg["dcn_raw"],
        model_flops=model_flops,
    )
    return {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "variant": variant,
        "devices": n_dev,
        "n_micro": n_micro,
        # Every silent spec_for drop, named: a replicated 8B-param tensor
        # should be a report line, not a surprise OOM (report.py renders).
        "sharding_fallbacks": shr.param_fallbacks(cfg, mesh),
        "compile_s": t_compile,
        "probe_compile_s": t_probe,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "total_hbm_bytes": (mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                + mem.temp_size_in_bytes
                                - mem.alias_size_in_bytes),
        },
        "hbm_traffic_model": mm,
        "hlo_bytes_accessed_upper_bound": agg["bytes"],
        "collectives": {
            "ici_bytes": agg["ici"],          # TPU-corrected (bf16 reductions)
            "dcn_bytes": agg["dcn"],
            "ici_bytes_raw": agg["ici_raw"],  # as compiled for CPU
            "dcn_bytes_raw": agg["dcn_raw"],
            "n_ops": len(ops_sample),
            "by_op": _summarize_ops(ops_sample),
        },
        "roofline": roof.to_dict(),
    }


def _summarize_ops(ops):
    agg = {}
    for o in ops:
        key = o["op"] + ("/dcn" if o["cross_pod"] else "")
        a = agg.setdefault(key, {"count": 0, "wire_bytes": 0.0})
        a["count"] += 1
        a["wire_bytes"] += o["wire_bytes"]
    return agg


# ------------------------------------------------------------ perf variants

def apply_variant(cfg: ModelConfig, variant: str):
    """Named perf-iteration variants (hillclimb experiments, §Perf).

    Compound variants combine with '+': e.g. ``tp4+seq_shard``.
    Returns (cfg, model_axis_size)."""
    import dataclasses as dc

    from repro.core.division_modes import DivisionConfig

    model_axis = 16
    for v in variant.split("+"):
        if v == "base":
            continue
        elif v == "exact_div":      # paper-baseline comparison: XLA divides
            cfg = dc.replace(cfg, division=DivisionConfig(mode="exact"))
        elif v == "div_paper_n5":   # paper-faithful: n=5, 53-bit, §6 schedule
            cfg = dc.replace(cfg, division=DivisionConfig(
                mode="taylor", n_iters=5, precision_bits=53, schedule="paper"))
        elif v == "no_remat":
            cfg = dc.replace(cfg, remat=False)
        elif v == "micro2x":
            cfg = dc.replace(cfg, train_microbatch_size=max(
                1, cfg.train_microbatch_size * 2))
        elif v == "micro_half":
            cfg = dc.replace(cfg, train_microbatch_size=max(
                1, cfg.train_microbatch_size // 2))
        elif v == "seq_shard":      # Megatron-style sequence parallelism
            cfg = dc.replace(cfg, sharding_rules={
                **cfg.sharding_rules, "__seq_shard__": "model"})
        elif v == "kvseq":          # flash-decoding: KV cache seq over model
            cfg = dc.replace(cfg, sharding_rules={
                **cfg.sharding_rules, "__kv_seq_shard__": "model"})
        elif v == "flash":          # fused flash-attention kernel (memmodel)
            cfg = dc.replace(cfg, use_flash_kernel=True)
        elif v == "ep_tp":          # MoE: experts local, expert-FF over model
            cfg = dc.replace(cfg, sharding_rules={
                **cfg.sharding_rules, "experts": None, "expert_mlp": "model"})
        elif v == "ep_model":       # MoE: experts over model axis
            cfg = dc.replace(cfg, sharding_rules={
                **cfg.sharding_rules, "experts": "model", "expert_mlp": None})
        elif v == "sort_dispatch":  # megablocks-style MoE position assignment
            cfg = dc.replace(cfg, moe_dispatch="sort")
        elif v == "local_dispatch":  # shard-local gather dispatch (collective-free)
            cfg = dc.replace(cfg, moe_dispatch="local")
        elif v == "optbf16":        # bf16 optimizer moments (fit at low TP)
            cfg = dc.replace(cfg, opt_state_dtype="bfloat16")
        elif v.startswith("tp"):    # tensor-parallel degree (data = 256/tp)
            model_axis = int(v[2:])
        elif v.startswith("chunk"):
            cfg = dc.replace(cfg, attn_chunk=int(v[5:]))
        elif v.startswith("mb"):    # absolute microbatch size
            cfg = dc.replace(cfg, train_microbatch_size=int(v[2:]))
        else:
            raise ValueError(f"unknown variant {v}")
    return cfg, model_axis


# --------------------------------------------------------------------- main

def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--variant", default="base")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    archs = [a for a in ARCH_IDS if a != "paper_fpdiv"] if args.all else [args.arch]
    for arch in archs:
        cfg = get_config(arch)
        shps = ([s.name for s in shapes_for(cfg)] if (args.all or not args.shape)
                else [args.shape])
        meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
        for s in shps:
            for m in meshes:
                cells.append((arch, s, m))

    failures = 0
    for arch, s, m in cells:
        tag = f"{arch}_{s}_{m}" + (f"_{args.variant}" if args.variant != "base" else "")
        try:
            res = run_cell(arch, s, m == "multi", variant=args.variant)
            path = os.path.join(args.out, tag + ".json")
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(f"[ok] {tag}: bound={r['bound']} "
                  f"t=(c {r['t_compute']:.4f}, m {r['t_memory']:.4f}, "
                  f"x {r['t_collective']:.4f})s mfu={r['mfu']:.3f} "
                  f"compile={res['compile_s']:.0f}s", flush=True)
        except Exception as e:
            failures += 1
            print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"{failures} cells failed")


if __name__ == "__main__":
    main()
