"""Render the roofline table (EXPERIMENTS.md §Roofline) from dry-run JSONs.

  PYTHONPATH=src python -m repro.launch.report --dir experiments/dryrun
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(d: str):
    cells = []
    for path in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def fmt_bytes(b):
    b = max(0.0, b)  # affine-probe extrapolation can leave tiny negatives
    if b >= 1e9:
        return f"{b/1e9:.1f}G"
    if b >= 1e6:
        return f"{b/1e6:.1f}M"
    return f"{b/1e3:.0f}K"


def table(cells, mesh="single", variant="base"):
    rows = [c for c in cells if c["mesh"] == mesh and c.get("variant", "base") == variant]
    rows.sort(key=lambda c: (c["arch"], c["shape"]))
    out = []
    out.append("| arch | shape | t_compute | t_memory | t_collective | bound "
               "| t_step | MFU | flops_eff | HBM/dev | fits | ICI | DCN |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|---|---|")
    HBM_CAP = 16 * 1024**3  # v5e
    for c in rows:
        r = c["roofline"]
        mem = c["memory"]["total_hbm_bytes"]
        fits = "yes" if mem <= HBM_CAP else "**NO**"
        out.append(
            f"| {c['arch']} | {c['shape']} | {r['t_compute']*1e3:.1f}ms "
            f"| {r['t_memory']*1e3:.1f}ms | {r['t_collective']*1e3:.1f}ms "
            f"| **{r['bound']}** | {r['t_step']*1e3:.1f}ms "
            f"| {r['mfu']:.3f} | {r['flops_efficiency']:.2f} "
            f"| {fmt_bytes(mem)} | {fits} | {fmt_bytes(r['ici_bytes'])} "
            f"| {fmt_bytes(r['dcn_bytes'])} |")
    return "\n".join(out)


def fallbacks_section(cells, mesh="single", variant="base"):
    """Per-cell table of silent sharding drops (rules.param_fallbacks).

    Every (param, dim) whose rule named a mesh axis that was dropped —
    duplicate use, axis missing, or size not divisible — with the full
    replicated byte size attached. Empty when every rule resolved.
    """
    rows = [c for c in cells
            if c["mesh"] == mesh and c.get("variant", "base") == variant
            and c.get("sharding_fallbacks")]
    if not rows:
        return ""
    seen = set()
    out = ["", "### Sharding fallbacks (replicated despite a rule)", "",
           "| arch | param | shape | axis -> mesh axis | reason | bytes |",
           "|---|---|---|---|---|---|"]
    for c in rows:
        for fb in c["sharding_fallbacks"]:
            key = (c["arch"], fb["param"], fb["dim"])
            if key in seen:     # one line per param/dim, not per shape cell
                continue
            seen.add(key)
            out.append(
                f"| {c['arch']} | {fb['param']} | "
                f"{'x'.join(str(s) for s in fb['shape'])} "
                f"| {fb['logical_axis']} -> {fb['mesh_axis']} "
                f"(dim {fb['dim']}: {fb['dim_size']} % "
                f"{fb['mesh_axis_size']}) | {fb['reason']} "
                f"| {fmt_bytes(fb['bytes'])} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    print(table(cells, args.mesh, args.variant))
    fb = fallbacks_section(cells, args.mesh, args.variant)
    if fb:
        print(fb)


if __name__ == "__main__":
    main()
