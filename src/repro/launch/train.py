"""Training launcher: single-host end-to-end driver.

On a real fleet each host runs this same script under
``jax.distributed.initialize`` (env-driven); the data pipeline shards by
host_index and the mesh comes from make_production_mesh. On this container it
drives the smoke/paper configs on the host mesh — the multi-pod path is
exercised by dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch paper_fpdiv --steps 200 \
      --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="paper_fpdiv")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--division", default=None,
                    choices=[None, "exact", "taylor", "taylor_pallas", "ilm"])
    args = ap.parse_args()

    import dataclasses

    from repro.configs import get_config, get_smoke_config
    from repro.core.division_modes import DivisionConfig
    from repro.data import DataConfig
    from repro.train.loop import LoopConfig, run

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if args.division:
        cfg = dataclasses.replace(cfg, division=DivisionConfig(mode=args.division))

    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=args.seed)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                      ckpt_dir=args.ckpt_dir, n_micro=args.n_micro,
                      seed=args.seed)
    out = run(cfg, loop, data_cfg)
    print(f"final loss: {out['losses'][-1]:.4f} after {out['last_step']} steps")


if __name__ == "__main__":
    main()
