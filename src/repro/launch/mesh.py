"""Production mesh builders.

Single pod: (data=16, model=16) — 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) — 512 chips; the 'pod' axis is pure
data-parallel and maps to DCN (gradients crossing it can be int8-compressed,
see optim.compress). Functions, not module constants: importing this module
never touches jax device state.
"""
from __future__ import annotations

import jax


def _axis_type_kwargs(n_axes: int) -> dict:
    """axis_types landed after jax 0.4.x; Auto is the default either way."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n_axes}


def make_production_mesh(*, multi_pod: bool = False,
                         model: int = 16) -> jax.sharding.Mesh:
    """256 chips/pod; ``model`` sets the TP degree (data = 256/model).
    Non-default TP is a §Perf hillclimb lever (tp4/tp8 variants)."""
    data = 256 // model
    shape = (2, data, model) if multi_pod else (data, model)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)))


def make_host_mesh(model: int = 1) -> jax.sharding.Mesh:
    """Small mesh over whatever devices exist (tests / examples).

    Raises ValueError (not a bare assert, which ``python -O`` strips into a
    garbage-shaped mesh) when ``model`` exceeds or doesn't divide the host's
    device count.
    """
    n = jax.device_count()
    if model < 1:
        raise ValueError(f"model={model} must be >= 1")
    if model > n:
        raise ValueError(
            f"model={model} exceeds the {n} available device(s); force more "
            "with XLA_FLAGS=--xla_force_host_platform_device_count=N or "
            "lower the model-parallel degree")
    if n % model != 0:
        raise ValueError(
            f"device count {n} is not divisible by model={model}")
    return jax.make_mesh((n // model, model), ("data", "model"),
                         **_axis_type_kwargs(2))
