"""Synthetic sharded LM data pipeline.

Deterministic, seekable, host-sharded: batch `step` is a pure function of
(seed, step, host_slice), so a restarted/rescheduled job resumes mid-epoch
with zero coordination — the fault-tolerance story depends on this.

The token stream is a mixture of Zipf-distributed unigrams and short
arithmetic-progression motifs so smoke-training has learnable structure
(pure-uniform tokens would give a flat loss). A background thread prefetches
``prefetch`` batches ahead of the training loop.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    motif_frac: float = 0.5  # fraction of positions covered by learnable motifs


class SyntheticLM:
    """Host-sharded synthetic corpus. ``host_index``/``host_count`` slice the
    global batch; every host generates only its rows (no cross-host IO)."""

    def __init__(self, cfg: DataConfig, host_index: int = 0, host_count: int = 1):
        assert cfg.global_batch % host_count == 0
        self.cfg = cfg
        self.host_index = host_index
        self.host_count = host_count
        self.local_batch = cfg.global_batch // host_count
        # Zipf unigram table (renormalized over vocab)
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = ranks ** (-cfg.zipf_a)
        self._p = p / p.sum()

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        """Batch for ``step``: tokens (local_batch, seq_len+1) -> inputs/labels."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_index]))
        n = cfg.seq_len + 1
        toks = rng.choice(cfg.vocab, size=(self.local_batch, n), p=self._p)
        # learnable motifs: arithmetic runs  t, t+1, t+2, ...
        n_motifs = max(1, int(cfg.motif_frac * n / 8))
        for b in range(self.local_batch):
            starts = rng.integers(0, max(1, n - 8), size=n_motifs)
            bases = rng.integers(0, cfg.vocab - 8, size=n_motifs)
            for s, base in zip(starts, bases):
                toks[b, s:s + 8] = base + np.arange(8)
        toks = toks.astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def iter(self, start_step: int = 0, prefetch: int = 2) -> Iterator[Dict]:
        """Prefetching iterator, resumable from any step."""
        q: queue.Queue = queue.Queue(maxsize=prefetch)
        stop = threading.Event()

        def producer():
            s = start_step
            while not stop.is_set():
                q.put(self.batch(s))
                s += 1

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
