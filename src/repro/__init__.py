"""repro: a production-grade JAX/TPU framework built around the paper
"A Floating Point Division Unit based on Taylor-Series Expansion and the
Iterative Logarithmic Multiplier" (Karani et al., 2017).

Public API:
  repro.core        — the paper's arithmetic (seeds, taylor, ilm, powering)
  repro.kernels     — Pallas TPU kernels (+ jnp oracles)
  repro.workloads   — division-consumer workloads (K-Means, Givens QR)
  repro.eval        — ULP conformance, golden vectors, workload metrics
  repro.models      — transformer/SSM/MoE model zoo
  repro.configs     — the 10 assigned architectures + paper demo config
  repro.train       — fault-tolerant distributed training
  repro.serving     — prefill/decode engine
  repro.launch      — production-mesh dry-run + roofline analysis
"""

__version__ = "1.0.0"
