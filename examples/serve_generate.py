"""Serving example: prefill + batched greedy decode across cache types.

Generates from three different architecture families (full attention,
sliding-window, SSM) to demonstrate the per-layer-kind cache machinery.

Run: PYTHONPATH=src python examples/serve_generate.py
"""
import jax

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import ServingEngine


def main():
    for arch in ["tinyllama_1_1b", "gemma3_12b", "mamba2_780m"]:
        cfg = get_smoke_config(arch)
        params = init_params(cfg, jax.random.PRNGKey(0))
        engine = ServingEngine(cfg, params, max_len=128)
        prompt = list(range(1, 33))
        out = engine.generate(prompt, max_new=12)
        print(f"{cfg.name:18s} ({cfg.family:6s}) prompt=32 toks -> {out}")

    # batched requests: one prefill + lockstep decode across 4 slots
    cfg = get_smoke_config("tinyllama_1_1b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=128)
    prompts = [list(range(1, 17)), list(range(5, 29)),
               list(range(40, 72)), [7, 8, 9]]
    outs = engine.generate_batch(prompts, max_new=8)
    for p, o in zip(prompts, outs):
        print(f"batched: prompt len {len(p):2d} -> {o}")


if __name__ == "__main__":
    main()
