"""End-to-end training driver: a small LM with every division site running
the paper's Taylor-series unit, with checkpointing and auto-resume.

Defaults to a ~10M-param model for a few hundred steps (CPU-friendly);
--arch paper_fpdiv trains the 134M paper demo config.

Run: PYTHONPATH=src python examples/train_lm.py --steps 300
"""
import argparse
import dataclasses

from repro.configs import get_config, get_smoke_config
from repro.configs.base import ModelConfig
from repro.core.division_modes import DivisionConfig
from repro.data import DataConfig
from repro.train.loop import LoopConfig, run

QUICK_LM = ModelConfig(
    name="quickstart-lm-10m",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=8, n_kv_heads=4, head_dim=32,
    d_ff=1024,
    vocab=8192,
    remat=False,
    division=DivisionConfig(mode="taylor", n_iters=2, precision_bits=24),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="quick")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--division", default="taylor",
                    choices=["exact", "taylor", "ilm"])
    args = ap.parse_args()

    if args.arch == "quick":
        cfg = QUICK_LM
    else:
        cfg = get_config(args.arch)
    cfg = dataclasses.replace(cfg, division=DivisionConfig(mode=args.division))

    from repro.models import param_count
    print(f"training {cfg.name}: {param_count(cfg)/1e6:.1f}M params, "
          f"division mode = {args.division}")
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.global_batch, seed=0)
    loop = LoopConfig(total_steps=args.steps, ckpt_every=100,
                      ckpt_dir=args.ckpt_dir, log_every=20)
    out = run(cfg, loop, data_cfg)
    l = out["losses"]
    print(f"loss: {l[0]:.4f} -> {l[-1]:.4f} over {out['last_step']} steps")
    assert l[-1] < l[0], "training did not improve loss"


if __name__ == "__main__":
    main()
