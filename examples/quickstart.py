"""Quickstart: the paper's floating-point division unit, in five minutes.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import jax
import jax.numpy as jnp

from repro.core import seeds, taylor, ilm, powering
from repro.core.division_modes import DivisionConfig, recip, softmax


def main():
    print("=" * 72)
    print("1. Piecewise-linear seed segments (paper §3, Table I)")
    table = seeds.compute_segments(n_iters=5, precision_bits=53)
    print(f"   segments for n=5 @ 53 bits: {np.round(table.boundaries[1:], 5)}")
    print(f"   paper Table I:              {seeds.PAPER_TABLE_I}")
    print(f"   single linear seed on [1,2] would need "
          f"{seeds.iterations_required(1, 2, 53)} iterations (paper: 17)")

    print("=" * 72)
    print("2. Taylor-series reciprocal (paper §2) — precision is a dial")
    x = jnp.asarray(np.random.default_rng(0).uniform(0.1, 100, 10_000),
                    jnp.float32)
    for n, prec in [(1, 12), (2, 24), (5, 53)]:
        cfg = DivisionConfig(mode="taylor", n_iters=n, precision_bits=prec)
        r = jax.jit(lambda v: recip(v, cfg))(x)
        err = float(jnp.max(jnp.abs(r * x - 1)))
        print(f"   n={n} ({prec}-bit table): max rel err of reciprocal = {err:.2e}")

    print("=" * 72)
    print("3. Iterative Logarithmic Multiplier (paper §4) — accuracy dial")
    rng = np.random.default_rng(1)
    a = rng.integers(1, 2**16, 20_000).astype(np.uint64)
    b = rng.integers(1, 2**16, 20_000).astype(np.uint64)
    for iters in (1, 2, 4, 16):
        p = ilm.ilm_mul_np(a, b, iters)
        rel = float(np.max((a * b - p) / (a * b)))
        print(f"   {iters:2d} iteration(s): worst product error = {rel:.4%}")

    print("=" * 72)
    print("4. Powering unit (paper §6): odd by multiply, even by square")
    print(f"   schedule for x^2..x^5: {powering.schedule(5)}")
    hw = powering.hw_cost()
    print(f"   squaring unit area ratio vs multiplier: {hw['area_ratio']:.1%}"
          f"  (<50% as claimed in §5)")

    print("=" * 72)
    print("5. Where it lands in an LLM: softmax through the division unit")
    logits = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32) * 3
    s_exact = softmax(logits, -1, DivisionConfig(mode="exact"))
    s_tsdiv = softmax(logits, -1, DivisionConfig(mode="taylor"))
    print(f"   max |softmax_taylor - softmax_exact| = "
          f"{float(jnp.max(jnp.abs(s_tsdiv - s_exact))):.2e}")
    print("done.")


if __name__ == "__main__":
    main()
