"""Benchmark harness: one function per paper table/figure + kernel/e2e perf.

Prints ``name,us_per_call,derived`` CSV rows (derived = the quantity the
paper's table reports). Writes the full results to benchmarks/results.json.

  PYTHONPATH=src python -m benchmarks.run            # all
  PYTHONPATH=src python -m benchmarks.run --only segments_table
  PYTHONPATH=src python -m benchmarks.run --only workloads [--quick]

The division-perf benches (``workloads``, ``tiled_divide``) additionally
merge their rows into ``BENCH_div.json`` at the repo root — the committed
perf-trajectory artifact (wall-clock + workload-level accuracy per division
mode, one snapshot per PR). On this container every Pallas cell runs
CPU-interpret, so those absolute numbers are proxies; the jnp-mode rows are
compiled XLA and are fair CPU comparisons (see docs/numerics.md).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

RESULTS = {}
QUICK = False

_BENCH_DIV = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                          os.pardir, "BENCH_div.json")
_BENCH_DIV_KEYS = ("workloads", "tiled_divide", "consumers", "serving",
                   "sharding")


def _write_bench_div():
    """Merge the division-perf RESULTS sections into BENCH_div.json.

    Merging (rather than overwriting) lets ``--only workloads`` and
    ``--only tiled_divide`` each refresh their own section without erasing
    the other's trajectory point.
    """
    import jax

    path = os.path.abspath(_BENCH_DIV)
    doc = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError):
            doc = {}
    doc["meta"] = {
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "pallas_interpret": jax.default_backend() != "tpu",
        "note": ("Pallas cells run interpret-mode off-TPU: their wall-clock "
                 "is a functional proxy, not kernel perf. jnp-mode rows are "
                 "compiled XLA. TPU re-run pending (ROADMAP open item)."),
    }
    for k in _BENCH_DIV_KEYS:
        if k in RESULTS:
            # quick is stamped per section, not on the global meta: sections
            # merge independently, so a CI-smoke refresh of one must not
            # relabel a retained full-run trajectory point in the other.
            doc[k] = {"quick": bool(QUICK), **RESULTS[k]}
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    print(f"# wrote {path}")


def _block(out):
    """Wait for async (jax) results; no-op for plain values."""
    leaves = out if isinstance(out, (tuple, list)) else (out,)
    for o in leaves:
        if hasattr(o, "block_until_ready"):
            o.block_until_ready()


def _time_us(fn, *args, reps: int = 5, warmup: int = 2, ret_out: bool = False):
    out = None
    for _ in range(warmup):
        out = fn(*args)
    _block(out)          # async warmup work must not bleed into the window
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    _block(out)
    us = (time.perf_counter() - t0) / reps * 1e6
    return (us, out) if ret_out else us


def bench_segments_table():
    """Paper Table I: segment boundaries for n=5, 53-bit precision."""
    from repro.core import seeds

    t0 = time.perf_counter()
    table = seeds.compute_segments(5, 53)
    us = (time.perf_counter() - t0) * 1e6
    ours = np.round(table.boundaries[1:], 5).tolist()
    RESULTS["segments_table"] = {
        "ours": ours, "paper": seeds.PAPER_TABLE_I,
        "n_segments": table.n_segments,
        "max_rel_dev": float(np.max(np.abs(
            (np.array(ours) - np.array(seeds.PAPER_TABLE_I))
            / np.array(seeds.PAPER_TABLE_I)))),
    }
    print(f"segments_table,{us:.1f},n_segments={table.n_segments}"
          f";b0={ours[0]};paper_b0={seeds.PAPER_TABLE_I[0]}")


def bench_taylor_iters():
    """Paper §3 iteration-count claims + measured error vs n."""
    from repro.core import seeds, taylor
    import math

    rows = {}
    rows["single_segment_iters"] = seeds.iterations_required(1, 2, 53)   # paper: 17
    rows["two_segment_iters"] = max(
        seeds.iterations_required(1, math.sqrt(2), 53),
        seeds.iterations_required(math.sqrt(2), 2, 53))                  # paper: 15
    table = seeds.compute_segments(5, 53)
    rng = np.random.default_rng(0)
    x = rng.uniform(1, 2, 200_000)
    err_by_n = {}
    for n in range(0, 6):
        t0 = time.perf_counter()
        r = taylor.reciprocal_np(x, table, n_iters=n, schedule="paper")
        us = (time.perf_counter() - t0) * 1e6
        err = float(np.max(np.abs(r * x - 1)))
        err_by_n[n] = {"max_err": err, "bound": table.max_error_bound(n),
                       "bits": -np.log2(err) if err > 0 else 60}
        print(f"taylor_n{n},{us:.1f},max_err={err:.3e};bits={err_by_n[n]['bits']:.1f}")
    RESULTS["taylor_iters"] = {**rows, "err_by_n": err_by_n}
    print(f"taylor_iters,0,single_seg={rows['single_segment_iters']}(paper=17);"
          f"two_seg={rows['two_segment_iters']}(paper=15;eq17_gives_10)")


def bench_ilm_accuracy():
    """ILM error vs iterations (paper §4 accuracy/iterations trade)."""
    from repro.core import ilm

    rng = np.random.default_rng(1)
    a = rng.integers(1, 2**16, 100_000).astype(np.uint64)
    b = rng.integers(1, 2**16, 100_000).astype(np.uint64)
    exact = a * b
    rows = {}
    for iters in (1, 2, 3, 4, 6, 8, 16):
        t0 = time.perf_counter()
        p = ilm.ilm_mul_np(a, b, iters)
        us = (time.perf_counter() - t0) * 1e6
        rel = (exact - p).astype(np.float64) / exact.astype(np.float64)
        rows[iters] = {"max_rel": float(rel.max()),
                       "mean_rel": float(rel.mean()),
                       "exact_frac": float(np.mean(p == exact))}
        print(f"ilm_iter{iters},{us:.1f},max_rel={rel.max():.2e};"
              f"exact_frac={rows[iters]['exact_frac']:.3f}")
    RESULTS["ilm_accuracy"] = rows


def bench_powering_hw():
    """Paper §5 <50% hardware claim + §6 schedule op counts (both schedules)."""
    from repro.core import powering

    hw = powering.hw_cost()
    rows = {"area_ratio": hw["area_ratio"], "unit_ratio": hw["unit_ratio"],
            "op_counts": {}}
    for n in (3, 5, 7, 9, 17):
        rows["op_counts"][n] = {
            "paper": powering.op_counts(n, "paper"),
            "factored": powering.op_counts(n, "factored"),
        }
    RESULTS["powering_hw"] = rows
    print(f"powering_hw,0,area_ratio={hw['area_ratio']:.3f}(<0.5);"
          f"n5_paper={rows['op_counts'][5]['paper']};"
          f"n5_factored={rows['op_counts'][5]['factored']}")


def bench_kernel_throughput():
    """CPU-proxy kernel timings: tsdiv/softmax/rmsnorm vs XLA-native.

    Absolute numbers are CPU-interpret proxies; the TPU claim rides on the
    dry-run roofline (§Roofline), not these timings. jnp-mode (lowered FMA
    chains) runs compiled and IS a fair CPU comparison."""
    import jax
    import jax.numpy as jnp
    from repro.core import taylor
    from repro.core.seeds import compute_segments

    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.uniform(0.01, 100, (1024, 1024)).astype(np.float32))
    t24 = compute_segments(2, 24)

    f_exact = jax.jit(lambda v: 1.0 / v)
    f_taylor = jax.jit(lambda v: taylor.reciprocal(v, t24))
    f_taylor_paper = jax.jit(lambda v: taylor.reciprocal(v, t24, schedule="paper"))
    us_e = _time_us(f_exact, x)
    us_t = _time_us(f_taylor, x)
    us_p = _time_us(f_taylor_paper, x)
    print(f"recip_xla,{us_e:.1f},1Melem")
    print(f"recip_taylor_factored,{us_t:.1f},ratio={us_t/us_e:.2f}x")
    print(f"recip_taylor_paper,{us_p:.1f},ratio={us_p/us_e:.2f}x")

    sm_exact = jax.jit(lambda v: jax.nn.softmax(v, -1))
    from repro.core.division_modes import DivisionConfig, softmax as dmsoft
    sm_t = jax.jit(lambda v: dmsoft(v, -1, DivisionConfig(mode="taylor")))
    us_se = _time_us(sm_exact, x)
    us_st = _time_us(sm_t, x)
    print(f"softmax_xla,{us_se:.1f},1Melem")
    print(f"softmax_taylor,{us_st:.1f},ratio={us_st/us_se:.2f}x")
    RESULTS["kernel_throughput"] = {
        "recip_xla_us": us_e, "recip_taylor_us": us_t,
        "recip_taylor_paper_us": us_p,
        "softmax_xla_us": us_se, "softmax_taylor_us": us_st,
    }


def bench_ulp_accuracy():
    """Conformance grid: delivered ULP accuracy per (mode x schedule x n x dtype).

    The machine-readable twin is `python -m repro.eval.conformance --json`;
    this row format keeps it greppable next to the perf numbers."""
    from repro.eval import conformance

    report = conformance.run_conformance(quick=True)
    for c in report["cells"]:
        o = c["overall"]
        name = f"ulp_{c['op']}_{c['mode']}_{c['schedule']}_n{c['n_iters']}_{c['dtype']}"
        print(f"{name},{c['seconds'] * 1e6:.0f},max_ulp={o['max_ulp']:.3f};"
              f"mean_ulp={o['mean_ulp']:.4f};edge_fail={c['edge_failures']}")
    RESULTS["ulp_accuracy"] = report


def bench_rsqrt():
    """op=rsqrt: wall-clock vs lax.rsqrt + delivered max ULP per policy.

    The compensated-final-Newton rsqrt is the divide-free Givens-QR
    formulation's datapath; this row records both its cost next to the
    native op and its accuracy on the paired odd/even-exponent sweep
    (machine-readable twin: the op=rsqrt cells of the conformance grid).
    """
    import jax
    import jax.numpy as jnp
    from repro.core.division_modes import DivisionConfig, rsqrt as dmrsqrt
    from repro.eval import ulp

    n = 1 << 17 if QUICK else 1 << 20
    x = jnp.abs(jax.random.normal(jax.random.PRNGKey(0), (n,), jnp.float32)) + 0.01
    f_exact = jax.jit(jax.lax.rsqrt)
    f_taylor = jax.jit(lambda v: dmrsqrt(v, DivisionConfig(mode="taylor")))
    us_e = _time_us(f_exact, x)
    us_t = _time_us(f_taylor, x)
    print(f"rsqrt_xla,{us_e:.1f},{n}elem")
    print(f"rsqrt_taylor,{us_t:.1f},ratio={us_t/us_e:.2f}x")
    rows = {"rsqrt_xla_us": us_e, "rsqrt_taylor_us": us_t, "n": n}
    sweep = np.concatenate([np.abs(ulp.sweep_logspace(4096, "float32", 5)),
                            ulp.sweep_exponent_parity(2048, "float32", 6),
                            ulp.sweep_rsqrt_mantissa(4096, "float32", 7)])
    exact = 1.0 / np.sqrt(sweep.astype(np.float64))
    mask = ulp.oracle_mask(exact) & ulp.oracle_mask(sweep.astype(np.float64))
    for policy in ("gradual", "ftz"):
        cfgp = DivisionConfig(mode="taylor", underflow=policy)
        r = np.asarray(dmrsqrt(jnp.asarray(sweep), cfgp))
        mx = float(ulp.ulp_error(r, exact, where=mask).max())
        rows[f"max_ulp_{policy}"] = mx
        print(f"rsqrt_taylor_{policy},0,max_ulp={mx:.3f}")
    RESULTS["rsqrt"] = rows


def bench_e2e_softdiv():
    """End-to-end: smoke LM forward under exact vs taylor vs ilm division."""
    import dataclasses
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core.division_modes import DivisionConfig
    from repro.models import forward, init_params
    from repro.train.step import loss_fn

    cfg = get_smoke_config("paper_fpdiv")
    params = init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    rows = {}
    base_logits = None
    for mode in ("exact", "taylor", "ilm"):
        c = dataclasses.replace(cfg, division=DivisionConfig(mode=mode))
        f = jax.jit(lambda p, b: loss_fn(c, p, b)[0])
        us = _time_us(f, params, batch, reps=3, warmup=1)
        loss = float(f(params, batch))
        logits, _, _ = forward(c, params, tokens=toks, mode="train")
        if base_logits is None:
            base_logits = logits
            dev = 0.0
        else:
            dev = float(jnp.max(jnp.abs(logits - base_logits)))
        rows[mode] = {"loss": loss, "us": us, "logit_dev_vs_exact": dev}
        print(f"e2e_{mode},{us:.1f},loss={loss:.4f};logit_dev={dev:.2e}")
    RESULTS["e2e_softdiv"] = rows


def _workload_modes():
    """The BENCH_div mode set: taylor/factored n=2, goldschmidt, exact."""
    from repro.core.division_modes import DivisionConfig

    return [
        ("taylor_factored_n2", DivisionConfig(mode="taylor",
                                              schedule="factored", n_iters=2)),
        ("goldschmidt_n2", DivisionConfig(mode="goldschmidt", n_iters=2)),
        ("exact", DivisionConfig(mode="exact")),
    ]


def bench_workloads():
    """Division-powered workloads: K-Means + Givens QR, per mode x size.

    Wall-clock per call (jit-compiled, post-warmup) plus the workload-level
    accuracy deltas vs the XLA-exact twin on identical inits — the numbers
    that start the BENCH_div.json perf trajectory.
    """
    import jax
    import jax.numpy as jnp
    from repro.eval import workload_metrics as wm
    from repro.workloads import kmeans as km, qr as qrw

    kmeans_sizes = [(2048, 16, 8), (8192, 32, 16)]   # (N, D, K)
    qr_sizes = [(24, 16), (48, 32)]                  # (M, N)
    lloyd_iters = 8
    if QUICK:
        kmeans_sizes, qr_sizes, lloyd_iters = kmeans_sizes[:1], qr_sizes[:1], 4

    rows = {"kmeans": {}, "qr": {}}
    for n, d, k in kmeans_sizes:
        key = jax.random.PRNGKey(n)
        x = km.make_blobs(key, n, d, k)
        init = jnp.take(x, jnp.arange(k) * (n // k), axis=0)
        cell = {}
        exact_inertia = None
        for name, cfg in _workload_modes():
            f = jax.jit(lambda x, init, cfg=cfg: km.kmeans(
                x, cfg=cfg, init=init, n_iters=lloyd_iters).inertia)
            us, out = _time_us(f, x, init, ret_out=True)
            inertia = float(out)
            if name == "exact":
                exact_inertia = inertia
            cell[name] = {"us": us, "inertia": inertia}
            print(f"kmeans_{name}_n{n}d{d}k{k},{us:.1f},inertia={inertia:.6f}")
        for name in cell:
            cell[name]["inertia_delta_vs_exact"] = wm.relative_delta(
                cell[name]["inertia"], exact_inertia)
        rows["kmeans"][f"n{n}_d{d}_k{k}"] = cell
    for m, n in qr_sizes:
        a = jax.random.normal(jax.random.PRNGKey(m * n), (m, n), jnp.float32)
        cell = {}
        for name, cfg in _workload_modes():
            for via in ("div", "rsqrt"):
                f = jax.jit(lambda a, cfg=cfg, via=via: qrw.qr_givens(
                    a, cfg, via=via))
                us, (q, r) = _time_us(f, a, ret_out=True)
                res = wm.qr_residuals(q, r, a)
                cell[f"{name}_{via}"] = {"us": us, **res}
                print(f"qr_{name}_{via}_{m}x{n},{us:.1f},"
                      f"orth={res['orthogonality']:.2e};"
                      f"recon={res['reconstruction']:.2e}")
        rows["qr"][f"{m}x{n}"] = cell
    RESULTS["workloads"] = rows
    _write_bench_div()


def bench_tiled_divide():
    """Tiled fused divide kernel vs jnp.divide vs jnp-mode Taylor, per shape.

    Shapes include non-multiples of the (8, 128) tile so the ragged-last-tile
    path is what gets timed. Kernel wall-clock is interpret-mode off-TPU —
    a functional proxy (meta.pallas_interpret records this).
    """
    import jax
    import jax.numpy as jnp
    from repro.core import taylor
    from repro.core.seeds import compute_segments
    from repro.kernels import ops as kops

    shapes = [(512, 512), (513, 259), (1024, 1024)]
    if QUICK:
        shapes = [(513, 259)]
    t24 = compute_segments(2, 24)
    rng = np.random.default_rng(7)
    rows = {}
    for shape in shapes:
        a = jnp.asarray(np.ldexp(rng.uniform(1, 2, shape),
                                 rng.integers(-60, 60, shape)).astype(np.float32))
        b = jnp.asarray(np.ldexp(rng.uniform(1, 2, shape),
                                 rng.integers(-60, 60, shape)).astype(np.float32))
        f_xla = jax.jit(jnp.divide)
        f_jnp = jax.jit(lambda a, b: taylor.divide(a, b, t24))
        f_kern = jax.jit(lambda a, b: kops.tsdiv_divide(a, b))
        us_x = _time_us(f_xla, a, b)
        us_j = _time_us(f_jnp, a, b)
        us_k, out_k = _time_us(f_kern, a, b, ret_out=True)
        ref = np.asarray(a, np.float64) / np.asarray(b, np.float64)
        err = np.abs(np.asarray(out_k, np.float64) - ref)
        finite = np.isfinite(ref) & (np.abs(ref) >= 2.0 ** -126) \
            & (np.abs(ref) <= np.finfo(np.float32).max)
        max_rel = float(np.max(err[finite] / np.abs(ref[finite])))
        name = f"{shape[0]}x{shape[1]}"
        rows[name] = {"xla_us": us_x, "taylor_jnp_us": us_j,
                      "tiled_kernel_us": us_k, "kernel_max_rel_err": max_rel,
                      "ragged": shape[0] % 8 != 0 or shape[1] % 128 != 0}
        print(f"tiled_divide_{name},{us_k:.1f},xla={us_x:.1f}us;"
              f"jnp_taylor={us_j:.1f}us;max_rel={max_rel:.2e};"
              f"ragged={rows[name]['ragged']}")
    RESULTS["tiled_divide"] = rows
    _write_bench_div()


def bench_consumers():
    """Normalization consumers through the unit: softmax / rmsnorm /
    flash-attention x division modes x two shapes.

    Wall-clock per call (jit-compiled, post-warmup) plus the consumer-tier
    accuracy metrics (row-sum ULP-equivalents and vs-exact-twin integer ULP
    for the norms, max |dev| vs the exact twin for attention) — merged into
    BENCH_div.json as the ``consumers`` section. The Pallas rows run
    interpret-mode off-TPU (meta.pallas_interpret): functional proxies.
    """
    import jax
    import jax.numpy as jnp
    from repro.core.division_modes import (DivisionConfig, EXACT, attention,
                                           rmsnorm, softmax)
    from repro.eval import consumers as cons

    norm_shapes = [(256, 512), (64, 2048)]
    attn_shapes = [(4, 128, 64), (2, 256, 64)]     # (batch*heads, S, hd)
    if QUICK:
        norm_shapes, attn_shapes = norm_shapes[:1], attn_shapes[:1]
    modes = _workload_modes() + [
        ("taylor_pallas_n2", DivisionConfig(mode="taylor_pallas", n_iters=2)),
        ("goldschmidt_pallas_n2",
         DivisionConfig(mode="goldschmidt_pallas", n_iters=2)),
    ]
    rows = {"softmax": {}, "rmsnorm": {}, "flash_attention": {}}
    for shape in norm_shapes:
        rng = np.random.default_rng(shape[0] * shape[1])
        x = jnp.asarray(rng.normal(0, 4, shape).astype(np.float32))
        w = jnp.asarray(cons.rmsnorm_weight(shape[1], seed=7))
        sm_exact = np.asarray(softmax(x, -1, EXACT))
        rn_exact = np.asarray(rmsnorm(x, w, EXACT))
        oracle_sm = cons.softmax_oracle(np.asarray(x, np.float64))
        oracle_rn = cons.rmsnorm_oracle(np.asarray(x, np.float64),
                                        np.asarray(w, np.float64))
        sm_cell, rn_cell = {}, {}
        for name, cfg in modes:
            f_sm = jax.jit(lambda v, cfg=cfg: softmax(v, -1, cfg))
            us, out = _time_us(f_sm, x, ret_out=True)
            out = np.asarray(out)
            sm_cell[name] = {
                "us": us,
                "row_sum_max_ulp1": float(cons.row_sum_ulp1(out).max()),
                "vs_exact_max_ulp": cons.vs_exact_int_ulp(out, sm_exact,
                                                          oracle_sm),
            }
            f_rn = jax.jit(lambda v, w, cfg=cfg: rmsnorm(v, w, cfg))
            us, out = _time_us(f_rn, x, w, ret_out=True)
            rn_cell[name] = {
                "us": us,
                "vs_exact_max_ulp": cons.vs_exact_int_ulp(
                    np.asarray(out), rn_exact, oracle_rn),
            }
            print(f"softmax_{name}_{shape[0]}x{shape[1]},"
                  f"{sm_cell[name]['us']:.1f},"
                  f"row_sum={sm_cell[name]['row_sum_max_ulp1']:.2f}ulp;"
                  f"vs_exact={sm_cell[name]['vs_exact_max_ulp']}ulp")
            print(f"rmsnorm_{name}_{shape[0]}x{shape[1]},"
                  f"{rn_cell[name]['us']:.1f},"
                  f"vs_exact={rn_cell[name]['vs_exact_max_ulp']}ulp")
        key = f"{shape[0]}x{shape[1]}"
        rows["softmax"][key] = sm_cell
        rows["rmsnorm"][key] = rn_cell
    for bh, s, hd in attn_shapes:
        rng = np.random.default_rng(bh * s)
        q = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
        exact = np.asarray(attention(q, k, v, EXACT))
        cell = {}
        for name, cfg in modes:
            f = jax.jit(lambda q, k, v, cfg=cfg: attention(q, k, v, cfg))
            us, out = _time_us(f, q, k, v, reps=3, warmup=1, ret_out=True)
            dev = float(np.max(np.abs(np.asarray(out) - exact)))
            cell[name] = {"us": us, "max_dev_vs_exact": dev}
            print(f"attention_{name}_{bh}x{s}x{hd},{us:.1f},"
                  f"max_dev={dev:.2e}")
        rows["flash_attention"][f"{bh}x{s}x{hd}"] = cell
    RESULTS["consumers"] = rows
    _write_bench_div()


def bench_serving():
    """Serving trajectory: prefill ms + decode tokens/sec through the engine.

    paper_fpdiv smoke LM, batch x division mode (taylor factored n=2,
    goldschmidt, taylor_pallas, exact). Prefill and decode are the engine's
    own jit'd steps (compiled-exec timings, post-warmup) over unequal-length
    prompts, so the padded-prompt masking path is what gets timed — merged
    into BENCH_div.json as the ``serving`` section. The taylor_pallas rows
    run interpret-mode off-TPU (meta.pallas_interpret): functional proxies.
    """
    import jax
    import jax.numpy as jnp
    from repro.configs import get_smoke_config
    from repro.core.division_modes import DivisionConfig
    from repro.models import init_params
    from repro.serving import ServingEngine, pad_cache_to

    cfg0 = get_smoke_config("paper_fpdiv")
    params = init_params(cfg0, jax.random.PRNGKey(0))
    prompt_len = 16 if QUICK else 32
    max_new = 8 if QUICK else 16
    batches = [1, 8]
    modes = _workload_modes() + [
        ("taylor_pallas_n2", DivisionConfig(mode="taylor_pallas", n_iters=2)),
    ]
    reps, warmup = (2, 1) if QUICK else (5, 2)
    rows = {}
    for B in batches:
        # unequal lengths exercise the padded-prompt masking path
        lens = [max(4, prompt_len - 3 * i) for i in range(B)]
        prompts = [list(range(1, L + 1)) for L in lens]
        cell = {}
        for name, div in modes:
            eng = ServingEngine(cfg0, params, division=div,
                                max_len=prompt_len + max_new + 16)
            pad_to = eng._pad_to(max(lens))
            toks = np.zeros((B, pad_to), np.int32)
            for i, p in enumerate(prompts):
                toks[i, :len(p)] = p
            toks = jnp.asarray(toks)
            lengths = jnp.asarray(lens, jnp.int32)
            us_pre = _time_us(lambda: eng._prefill_tok(toks, lengths)[0],
                              reps=reps, warmup=warmup)
            last, cache = eng._prefill_tok(toks, lengths)
            cache = pad_cache_to(cache, pad_to, eng.max_len, eng.cfg)
            tok = jnp.argmax(last, axis=-1)[:, None].astype(jnp.int32)
            us_dec = _time_us(lambda: eng._decode(cache, tok, lengths)[0],
                              reps=reps * max_new, warmup=warmup)
            cell[name] = {
                "prefill_ms": us_pre / 1e3,
                "decode_us_per_step": us_dec,
                "decode_tok_s": B / (us_dec * 1e-6),
            }
            print(f"serving_{name}_b{B},{us_dec:.1f},"
                  f"prefill={us_pre / 1e3:.2f}ms;"
                  f"tok_s={cell[name]['decode_tok_s']:.1f}")
        rows[f"batch{B}"] = cell
    rows["config"] = {"arch": cfg0.name, "prompt_len": prompt_len,
                      "prompt_lens": "unequal (padded-prompt path)",
                      "max_new": max_new}
    RESULTS["serving"] = rows
    _write_bench_div()


def bench_sharding():
    """Mesh scaling: 1 vs 8 virtual devices, tiled divide + K-Means.

    jax locks the device count at first init, so each point runs as a
    subprocess (``repro.sharding.scaling``) under its own
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``. At N=1 the
    mesh-aware dispatch falls back to the single-device paths, so the pair
    is a true sharded-vs-unsharded comparison — on this container all 8
    virtual devices share one host CPU, so the speedup column measures
    dispatch overhead and XLA's intra-host parallelism, not an 8x fleet
    (recorded as-is in the ``sharding`` section of BENCH_div.json).
    """
    import subprocess
    import sys

    src = os.path.abspath(os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir, "src"))
    points = 200_000 if QUICK else 1_000_000
    rows_, cols = (1024, 256) if QUICK else (2048, 384)
    reps = 2 if QUICK else 3
    rows = {}
    for n_dev in (1, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
        env["JAX_PLATFORMS"] = "cpu"
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        cmd = [sys.executable, "-m", "repro.sharding.scaling",
               "--points", str(points), "--rows", str(rows_),
               "--cols", str(cols), "--reps", str(reps)]
        proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                              timeout=1800)
        if proc.returncode != 0:
            raise RuntimeError(
                f"scaling driver failed at {n_dev} device(s):\n"
                f"{proc.stdout}\n{proc.stderr}")
        data = json.loads(proc.stdout.strip().splitlines()[-1])
        rows[f"devices{n_dev}"] = data
        print(f"sharding_divide_d{n_dev},{data['tiled_divide_us']:.1f},"
              f"shape={rows_}x{cols}")
        print(f"sharding_kmeans_d{n_dev},{data['kmeans_us']:.1f},"
              f"points={points};inertia={data['kmeans']['inertia']:.6f}")
    rows["speedup_8dev"] = {
        "tiled_divide": rows["devices1"]["tiled_divide_us"]
        / rows["devices8"]["tiled_divide_us"],
        "kmeans": rows["devices1"]["kmeans_us"]
        / rows["devices8"]["kmeans_us"],
    }
    print(f"sharding_speedup,0,"
          f"divide={rows['speedup_8dev']['tiled_divide']:.2f}x;"
          f"kmeans={rows['speedup_8dev']['kmeans']:.2f}x")
    RESULTS["sharding"] = rows
    _write_bench_div()


BENCHES = {
    "segments_table": bench_segments_table,
    "taylor_iters": bench_taylor_iters,
    "ilm_accuracy": bench_ilm_accuracy,
    "powering_hw": bench_powering_hw,
    "kernel_throughput": bench_kernel_throughput,
    "ulp_accuracy": bench_ulp_accuracy,
    "rsqrt": bench_rsqrt,
    "e2e_softdiv": bench_e2e_softdiv,
    "workloads": bench_workloads,
    "tiled_divide": bench_tiled_divide,
    "consumers": bench_consumers,
    "serving": bench_serving,
    "sharding": bench_sharding,
}


def main() -> None:
    global QUICK
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke sizing: one problem size per workload")
    args, _ = ap.parse_known_args()
    QUICK = args.quick
    names = [args.only] if args.only else list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    out = os.path.join(os.path.dirname(__file__), "results.json")
    with open(out, "w") as f:
        json.dump(RESULTS, f, indent=1, default=str)
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
