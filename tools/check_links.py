#!/usr/bin/env python
"""Relative-link checker for README.md and docs/*.md (the CI docs gate).

Walks every markdown link target in the checked files and fails (exit 1,
one line per break) if a relative target does not exist on disk. External
schemes (http/https/mailto) and pure in-page anchors are skipped — this
gate is about repo-internal file references surviving refactors, not about
the network.

    python tools/check_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys
from typing import List, Tuple

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)]*)\)")
# Inside the parens: a <bracketed> or bare target, optionally followed by a
# quoted title ([text](path "title") must still have its path checked).
MD_TARGET = re.compile(r"^(<[^>]*>|\S+)(?:\s+(?:\"[^\"]*\"|'[^']*'))?$")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def checked_files(root: pathlib.Path) -> List[pathlib.Path]:
    files = [root / "README.md"]
    files += sorted((root / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def broken_links(root: pathlib.Path) -> List[Tuple[pathlib.Path, str]]:
    broken = []
    for f in checked_files(root):
        for raw in MD_LINK.findall(f.read_text(encoding="utf-8")):
            m = MD_TARGET.match(raw.strip())
            if m is None:          # unparseable target — never skip silently
                broken.append((f, raw))
                continue
            target = m.group(1).strip("<>")
            if target.startswith(SKIP_PREFIXES):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            if not (f.parent / path).exists():
                broken.append((f, target))
    return broken


def main(argv: List[str]) -> int:
    root = pathlib.Path(argv[1]) if len(argv) > 1 else \
        pathlib.Path(__file__).resolve().parent.parent
    files = checked_files(root)
    if not files:
        print(f"check_links: no markdown files found under {root}")
        return 1
    broken = broken_links(root)
    for f, target in broken:
        print(f"check_links: {f.relative_to(root)}: broken link -> {target}")
    if not broken:
        print(f"check_links: {len(files)} files ok "
              f"({', '.join(str(f.relative_to(root)) for f in files)})")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
