"""Flash-attention Pallas kernel (online softmax + tsdiv normalization)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.kernels import ops, ref


@given(st.sampled_from([32, 64, 128]), st.sampled_from([16, 32, 64]),
       st.sampled_from([16, 32, 64]), st.booleans(), st.integers(0, 2**31 - 1))
@settings(max_examples=12, deadline=None)
def test_property_flash_matches_oracle(s, hd, bk, causal, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, s, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, s, hd)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=causal, block_q=min(32, s),
                            block_k=min(bk, s))
    e = ref.flash_attention_exact(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                               atol=3e-6, rtol=1e-4)


CASES = [
    # (bh, sq, hd, block_q, block_k, causal)
    (2, 256, 64, 128, 128, True),
    (3, 128, 32, 64, 32, True),
    (2, 256, 64, 128, 64, False),
    (1, 512, 128, 128, 128, True),
    (2, 64, 16, 64, 64, True),     # single block (degenerate)
]


@pytest.mark.parametrize("bh,s,hd,bq,bk,causal", CASES)
def test_flash_vs_exact(rng, bh, s, hd, bq, bk, causal):
    q = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    e = ref.flash_attention_exact(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                               atol=2e-6, rtol=1e-5)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(2, 128, 64)), jnp.bfloat16)
    o = ops.flash_attention(q, k, v)
    e = ref.flash_attention_exact(q, k, v)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(e, np.float32), atol=0.04)


def test_flash_4d_input(rng):
    """(B, H, S, hd) leading dims flatten onto the grid axis."""
    q = jnp.asarray(rng.normal(size=(2, 4, 128, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 4, 128, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 4, 128, 32)).astype(np.float32))
    o = ops.flash_attention(q, k, v)
    assert o.shape == (2, 4, 128, 32)
    e = ref.flash_attention_exact(q.reshape(8, 128, 32), k.reshape(8, 128, 32),
                                  v.reshape(8, 128, 32)).reshape(2, 4, 128, 32)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e), atol=2e-6)


def test_flash_long_context_streaming(rng):
    """Many key blocks: the online rescaling stays numerically stable."""
    q = jnp.asarray(rng.normal(size=(1, 1024, 32)).astype(np.float32)) * 3
    k = jnp.asarray(rng.normal(size=(1, 1024, 32)).astype(np.float32)) * 3
    v = jnp.asarray(rng.normal(size=(1, 1024, 32)).astype(np.float32))
    o = ops.flash_attention(q, k, v, block_q=128, block_k=64)
    e = ref.flash_attention_exact(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                               atol=5e-6, rtol=1e-4)


RAGGED_CASES = [
    # (bh, s, hd, block_q, block_k, causal): seq lens that are NOT block
    # multiples — the shapes the kernel used to hard-assert on.
    (2, 100, 32, 32, 32, True),
    (2, 100, 32, 32, 32, False),
    (1, 300, 16, 128, 64, True),
    (3, 77, 32, 32, 16, False),
]


@pytest.mark.parametrize("bh,s,hd,bq,bk,causal", RAGGED_CASES)
def test_flash_ragged_seq_lens(rng, bh, s, hd, bq, bk, causal):
    """Pad-and-mask in the ops wrapper: ragged sequences match the oracle
    (padded keys masked to NEG_INF in-kernel, padded q rows sliced off)."""
    q = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(bh, s, hd)).astype(np.float32))
    o = ops.flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk)
    assert o.shape == (bh, s, hd)
    e = ref.flash_attention_exact(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                               atol=5e-6, rtol=1e-4)
    assert np.all(np.isfinite(np.asarray(o)))


def test_flash_causal_skip_bit_identity(rng):
    """The above-diagonal early skip (pl.when on fully-masked k blocks) is
    bit-identical to running them: a skipped block contributes exactly
    p = exp(NEG_INF - m_prev) = 0."""
    from repro.kernels import flash_attention as fak

    q = jnp.asarray(rng.normal(size=(2, 256, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 256, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 256, 32)).astype(np.float32))
    for bq, bk in [(64, 32), (32, 64), (128, 128)]:
        o_skip = fak.flash_attention(q, k, v, causal=True, block_q=bq,
                                     block_k=bk, skip_masked_k=True)
        o_full = fak.flash_attention(q, k, v, causal=True, block_q=bq,
                                     block_k=bk, skip_masked_k=False)
        assert bool(jnp.all(o_skip == o_full)), (bq, bk)


def test_flash_goldschmidt_schedule(rng):
    """schedule="goldschmidt" runs the joint residual recurrence in-kernel
    for the 1/l normalization — same oracle tolerance as factored."""
    q = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
    o = ops.flash_attention(q, k, v, schedule="goldschmidt")
    e = ref.flash_attention_exact(q, k, v)
    np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                               atol=3e-6, rtol=1e-4)
    # and it is genuinely a different reciprocal path than factored: the
    # two schedules round differently on a fraction of lanes
    of = ops.flash_attention(q, k, v, schedule="factored")
    assert bool(jnp.any(o != of))
