"""First-class docs stay first-class: files exist, links resolve.

Runs the same checker CI's docs job runs (tools/check_links.py) so a broken
relative link in README.md / docs/*.md fails tier-1 locally, not just in CI.
"""
import pathlib
import subprocess
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent


def test_required_docs_exist():
    for rel in ("README.md", "docs/numerics.md", "docs/architecture.md",
                "ROADMAP.md", "BENCH_div.json"):
        assert (REPO / rel).exists(), f"missing {rel}"


def test_readme_covers_quickstart_and_caveat():
    text = (REPO / "README.md").read_text()
    # The commands a newcomer needs, and the CPU-interpret caveat readers
    # must see before quoting any table as a TPU number.
    for needle in ("python -m pytest", "repro.eval.conformance",
                   "benchmarks.run", "CPU-interpret", "docs/numerics.md"):
        assert needle in text, f"README.md lost {needle!r}"


def test_markdown_links_resolve():
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "check_links.py"), str(REPO)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
