"""Checkpointing: bit-exact roundtrip, atomicity, GC, incomplete rejection."""
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train import checkpoint as ck


def _tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16) * 1.5,
                   "c": jnp.asarray(3, jnp.int32)},
    }


def test_roundtrip_bit_exact(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 7, t)
    step, restored = ck.restore_latest(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(restored)):
        assert a.dtype == b.dtype
        assert bool(jnp.all(a == b))


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(str(tmp_path), s, t, keep=2)
    assert ck.latest_step(str(tmp_path)) == 5
    assert sorted(ck.all_steps(str(tmp_path))) == [4, 5]


def test_incomplete_checkpoint_ignored(tmp_path):
    t = _tree()
    ck.save(str(tmp_path), 1, t)
    # simulate a crash mid-write: dir exists but no COMPLETE marker
    broken = tmp_path / "step_0000000002"
    broken.mkdir()
    (broken / "meta.json").write_text("{}")
    assert ck.latest_step(str(tmp_path)) == 1  # ignores the broken one
    step, _ = ck.restore_latest(str(tmp_path), t)
    assert step == 1


def test_restore_missing_returns_like(tmp_path):
    t = _tree()
    step, restored = ck.restore_latest(str(tmp_path / "nope"), t)
    assert step is None
    assert restored is t


def test_hypothesis_roundtrip_dtypes(tmp_path):
    """Property-ish sweep: all framework dtypes survive the byte roundtrip."""
    for i, dt in enumerate([jnp.float32, jnp.bfloat16, jnp.float16,
                            jnp.int32, jnp.int8, jnp.uint32]):
        t = {"x": jnp.asarray(np.random.default_rng(i).integers(
            0, 100, (4, 5)), dt)}
        d = str(tmp_path / f"dt{i}")
        ck.save(d, 1, t)
        _, r = ck.restore_latest(d, t)
        assert r["x"].dtype == dt
        assert bool(jnp.all(r["x"] == t["x"]))
