"""Pallas kernels: shape/dtype sweeps, interpret mode vs pure-jnp oracles.

Two comparisons per kernel:
  * vs ref  (same algorithm)  — tight: <= few ulp (FMA-contraction noise only)
  * vs exact (true math)      — tolerance derived from the paper's eq. 17
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref

SHAPES = [(8, 128), (64, 256), (100, 130), (256, 512), (3, 7), (1, 1)]


def _rand(rng, shape, lo, hi, dtype=np.float32):
    return jnp.asarray(rng.uniform(lo, hi, shape).astype(dtype))


class TestTsdiv:
    @pytest.mark.parametrize("shape", SHAPES)
    def test_recip_vs_ref_and_exact(self, rng, shape):
        x = _rand(rng, shape, 0.01, 1000)
        k = ops.tsdiv_recip(x)
        r = ref.tsdiv_recip_ref(x)
        np.testing.assert_allclose(np.asarray(k), np.asarray(r), rtol=3e-7)
        e = np.asarray(ref.tsdiv_recip_exact(x))
        np.testing.assert_allclose(np.asarray(k), e, rtol=2**-20)

    @pytest.mark.parametrize("n_iters,prec,rtol", [(1, 12, 2**-11),
                                                   (2, 24, 2**-20),
                                                   (3, 30, 2**-21)])
    def test_precision_dial(self, rng, n_iters, prec, rtol):
        """The paper's accuracy dial: more iterations -> tighter result."""
        x = _rand(rng, (64, 256), 0.1, 100)
        k = ops.tsdiv_recip(x, n_iters=n_iters, precision_bits=prec)
        e = np.asarray(ref.tsdiv_recip_exact(x))
        np.testing.assert_allclose(np.asarray(k), e, rtol=rtol)

    @pytest.mark.parametrize("schedule", ["paper", "factored", "goldschmidt"])
    def test_schedules(self, rng, schedule):
        x = _rand(rng, (32, 256), 0.5, 2.0)
        k = ops.tsdiv_recip(x, schedule=schedule)
        np.testing.assert_allclose(
            np.asarray(k), np.asarray(ref.tsdiv_recip_ref(x, schedule=schedule)),
            rtol=3e-7)

    @pytest.mark.parametrize("shape", [(16, 128), (65, 40)])
    def test_divide(self, rng, shape):
        a = _rand(rng, shape, -50, 50)
        b = _rand(rng, shape, 0.1, 100)
        k = ops.tsdiv_divide(a, b)
        np.testing.assert_allclose(np.asarray(k), np.asarray(a) / np.asarray(b),
                                   rtol=2**-18, atol=1e-6)

    def test_negative_and_edges(self):
        x = jnp.asarray([[-2.0, 4.0, -0.5, 1.0, 3.0, -1.5, 8.0, 0.25]],
                        jnp.float32)
        k = np.asarray(ops.tsdiv_recip(x))
        np.testing.assert_allclose(k, 1.0 / np.asarray(x), rtol=2e-6)

    def test_bf16_passthrough(self, rng):
        x = _rand(rng, (32, 128), 0.1, 10).astype(jnp.bfloat16)
        k = ops.tsdiv_recip(x)
        assert k.dtype == jnp.bfloat16
        rel = np.abs(np.asarray(k, np.float32) * np.asarray(x, np.float32) - 1)
        assert rel.max() < 0.02


class TestShapeEdges:
    """pallas_applicable contract + the padded _to_2d/_from_2d round-trip."""

    def test_pallas_applicable(self):
        assert ops.pallas_applicable(jnp.float32(4.0))                 # 0-d
        assert ops.pallas_applicable(jnp.ones((1,), jnp.float32))      # 1 elem
        assert ops.pallas_applicable(jnp.ones((3,), jnp.bfloat16))
        assert not ops.pallas_applicable(jnp.ones((0,), jnp.float32))  # empty
        assert not ops.pallas_applicable(jnp.ones((4,), jnp.int32))

    def test_recip_0d_roundtrip(self):
        r = ops.tsdiv_recip(jnp.float32(4.0))
        assert r.shape == () and r.dtype == jnp.float32
        assert abs(float(r) - 0.25) < 1e-6

    def test_recip_1elem_roundtrip(self):
        r = ops.tsdiv_recip(jnp.asarray([2.0], jnp.float32))
        assert r.shape == (1,)
        assert abs(float(r[0]) - 0.5) < 1e-6

    def test_divide_0d_and_1elem(self):
        q = ops.tsdiv_divide(jnp.float32(6.0), jnp.float32(3.0))
        assert q.shape == () and abs(float(q) - 2.0) < 1e-5
        q1 = ops.tsdiv_divide(jnp.asarray([6.0], jnp.float32),
                              jnp.asarray([3.0], jnp.float32))
        assert q1.shape == (1,) and abs(float(q1[0]) - 2.0) < 1e-5

    def test_empty_falls_back_to_jnp(self):
        from repro.core import division_modes as dm

        e = dm.recip(jnp.ones((0,), jnp.float32),
                     dm.DivisionConfig(mode="taylor_pallas"))
        assert e.shape == (0,)

    def test_grad_through_0d_kernel(self):
        g = jax.grad(lambda v: ops.tsdiv_recip(v))(jnp.float32(4.0))
        assert abs(float(g) + 1 / 16) < 1e-5


class TestRmsnorm:
    @pytest.mark.parametrize("shape", [(4, 64), (16, 250), (2, 8, 96)])
    def test_vs_ref_and_exact(self, rng, shape):
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32)) * 3
        w = jnp.asarray(rng.normal(size=shape[-1:]).astype(np.float32))
        k = ops.rmsnorm(x, w)
        r = ref.rmsnorm_ref(x, w)
        np.testing.assert_allclose(np.asarray(k), np.asarray(r),
                                   rtol=1e-5, atol=1e-5)
        e = ref.rmsnorm_exact(x, w)
        np.testing.assert_allclose(np.asarray(k), np.asarray(e),
                                   rtol=1e-4, atol=1e-4)


class TestSoftmax:
    @pytest.mark.parametrize("shape", [(8, 128), (37, 250), (4, 16, 64)])
    def test_vs_exact(self, rng, shape):
        x = jnp.asarray(rng.normal(size=shape).astype(np.float32)) * 5
        k = ops.softmax(x)
        e = ref.softmax_exact(x)
        np.testing.assert_allclose(np.asarray(k), np.asarray(e),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(k).sum(-1), 1.0, rtol=1e-5)

    def test_extreme_logits(self):
        x = jnp.asarray([[-1e30, 0.0, 1.0, -1e30]], jnp.float32)
        k = np.asarray(ops.softmax(x))
        assert np.all(np.isfinite(k))
        np.testing.assert_allclose(k.sum(-1), 1.0, rtol=1e-5)


class TestIlmKernel:
    @pytest.mark.parametrize("shape", [(8, 128), (33, 70)])
    def test_exact_full_iters(self, rng, shape):
        a = jnp.asarray(rng.integers(0, 2**16, shape), jnp.uint32)
        b = jnp.asarray(rng.integers(0, 2**16, shape), jnp.uint32)
        k = ops.ilm_mul(a, b)
        assert bool(jnp.all(k == a * b))

    @pytest.mark.parametrize("iters", [1, 2, 4, 8])
    def test_matches_core_ref(self, rng, iters):
        a = jnp.asarray(rng.integers(1, 2**16, (16, 128)), jnp.uint32)
        b = jnp.asarray(rng.integers(1, 2**16, (16, 128)), jnp.uint32)
        k = ops.ilm_mul(a, b, iters=iters)
        r = ref.ilm_mul_ref(a, b, iters=iters)
        assert bool(jnp.all(k == r))

    def test_square(self, rng):
        a = jnp.asarray(rng.integers(0, 2**16, (16, 128)), jnp.uint32)
        assert bool(jnp.all(ops.ilm_square(a) == a * a))
