"""Serving-path correctness: the padded-prompt fix and the batching loop.

Pins the PR-6 bug fixes: (a) batched generation over unequal-length
(right-padded) prompts is token-identical to unpadded single-request
generation — the prefill logit is gathered at ``len(prompt) - 1`` and pad
positions are masked out of every cache kind; (b) ``pad_cache_to`` no longer
corrupts a sliding-window ring whose window equals the prefill length;
(c) ``serve()`` continuous batching (slot refill, per-request ``max_new``,
EOS release) reproduces ``generate()`` exactly; (d) embed-input and
encoder-decoder configs get a working hand-off or a clear ``ValueError``.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.configs import get_smoke_config
from repro.models import forward, init_params
from repro.serving import Request, ServingEngine, pad_cache_to


def _setup(arch, *, max_len=96, **engine_kw):
    """f32 + no-drop MoE capacity: bit-stable across batch compositions."""
    cfg = dataclasses.replace(get_smoke_config(arch), param_dtype="float32",
                              capacity_factor=8.0)
    if cfg.is_encoder_decoder:
        cfg = dataclasses.replace(cfg, encoder_seq=24)
    params = init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params, ServingEngine(cfg, params, max_len=max_len, **engine_kw)


# --------------------------------------------------- padded-prompt identity

@pytest.mark.parametrize("arch", ["paper_fpdiv", "gemma3_12b",
                                  "jamba_1_5_large"])
def test_batched_padded_matches_single(arch):
    """Unequal-length prompts (one exactly the window/chunk size of 16):
    generate_batch must be token-identical to per-request generate."""
    _, _, eng = _setup(arch)
    prompts = [list(range(1, 12)), list(range(3, 25)), list(range(5, 21))]
    singles = [eng.generate(p, max_new=5) for p in prompts]
    batch = eng.generate_batch(prompts, max_new=5)
    assert batch == singles


def test_generate_batch_input_validation():
    _, _, eng = _setup("paper_fpdiv")
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate_batch([])
    with pytest.raises(ValueError, match="empty prompt"):
        eng.generate_batch([[1, 2], []])
    with pytest.raises(ValueError, match="max_len"):
        eng.generate_batch([list(range(1, 90))], max_new=32)


# -------------------------------------------------------------- pad_cache_to

def test_pad_cache_to_ring_window_equals_prompt():
    """Regression: with sliding_window == prompt_len, the legacy shape
    heuristic padded the W-sized ring to max_len (corrupting ring-modulo
    indexing); the cfg-structural walk leaves rings alone and still grows the
    full-attention caches."""
    cfg = dataclasses.replace(get_smoke_config("gemma3_12b"),
                              param_dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    W = cfg.sliding_window
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, W), 0, cfg.vocab)
    _, cache, _ = forward(cfg, params, tokens=toks, mode="prefill")
    padded = pad_cache_to(cache, W, 64, cfg)
    shapes = {a.shape[-3] for a in jtu.tree_leaves(padded)}
    assert shapes == {W, 64}, f"rings must stay {W}, full KV grow to 64: {shapes}"
    # the legacy heuristic (no cfg) pads everything — the bug this pins
    legacy = {a.shape[-3] for a in jtu.tree_leaves(pad_cache_to(cache, W, 64))}
    assert legacy == {64}

    # end-to-end: decode past the window from a W-length prompt still matches
    eng = ServingEngine(cfg, params, max_len=64)
    single = eng.generate(list(range(1, W + 1)), max_new=W + 4)
    batch = eng.generate_batch([list(range(1, W + 1)), list(range(2, W - 3))],
                               max_new=W + 4)
    assert batch[0] == single


# ------------------------------------------------------- continuous batching

def test_serve_continuous_matches_generate():
    """4 requests through 2 slots: slot refill + per-request max_new, each
    output identical to a standalone generate()."""
    _, _, eng = _setup("paper_fpdiv")
    reqs = [Request(list(range(1, 10)), max_new=4),
            Request(list(range(2, 20)), max_new=6),
            Request(list(range(4, 11)), max_new=3),
            Request(list(range(7, 23)), max_new=5)]
    out = eng.serve(reqs, slots=2)
    assert out is not None and all(r.done for r in reqs)
    for r in reqs:
        assert r.out == eng.generate(r.tokens, max_new=r.max_new)


def test_serve_eos_release():
    """EOS stops a request early and frees its slot for the queue."""
    cfg, params, ref = _setup("paper_fpdiv")
    prompt = list(range(1, 10))
    full = ref.generate(prompt, max_new=6)
    eos = full[1]  # greedy-deterministic: the 2nd token becomes the EOS
    eng = ServingEngine(cfg, params, max_len=96, eos_id=eos)
    reqs = [Request(prompt, max_new=6), Request(list(range(2, 20)), max_new=4)]
    eng.serve(reqs, slots=1)  # one slot: EOS release must refill the queue
    assert reqs[0].done and reqs[0].out == full[:full.index(eos) + 1]
    assert reqs[1].done
    assert len(reqs[1].out) == 4 or reqs[1].out[-1] == eos


# ------------------------------------------------- embeds / enc-dec hand-off

def test_vlm_embeds_handoff_and_error():
    cfg, _, eng = _setup("llava_next_mistral_7b", max_len=64)
    e1 = jax.random.normal(jax.random.PRNGKey(2), (9, cfg.d_model))
    e2 = jax.random.normal(jax.random.PRNGKey(3), (14, cfg.d_model))
    singles = [eng.generate(embeds=e, max_new=4) for e in (e1, e2)]
    assert eng.generate_batch(None, max_new=4, embeds=[e1, e2]) == singles
    with pytest.raises(ValueError, match="embed_inputs"):
        eng.generate([1, 2, 3], max_new=2)
    with pytest.raises(ValueError, match="embed"):
        eng.serve([Request([1, 2, 3])])


def test_encdec_enc_embeds_handoff_and_error():
    cfg, _, eng = _setup("whisper_tiny", max_len=64)
    enc = jax.random.normal(jax.random.PRNGKey(5),
                            (2, cfg.encoder_seq, cfg.d_model))
    s0 = eng.generate([3, 4, 5, 6], max_new=4, enc_embeds=enc[0])
    s1 = eng.generate(list(range(7, 14)), max_new=4, enc_embeds=enc[1])
    batch = eng.generate_batch([[3, 4, 5, 6], list(range(7, 14))],
                               max_new=4, enc_embeds=enc)
    assert batch == [s0, s1]
    with pytest.raises(ValueError, match="enc_embeds"):
        eng.generate([1, 2], max_new=2)
    with pytest.raises(ValueError, match="encoder-decoder"):
        eng.serve([Request([1, 2])])
