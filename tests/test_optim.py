"""Optimizer: AdamW math, taylor-division mode, int8 compression convergence."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.division_modes import DivisionConfig
from repro.optim import adamw, compress


def _tiny_params():
    return {"w": jnp.asarray([[1.0, -2.0], [0.5, 3.0]], jnp.float32),
            "b": jnp.asarray([0.1, -0.1], jnp.float32)}


class TestAdamW:
    def test_matches_reference_formula(self):
        cfg = adamw.AdamWConfig(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8,
                                weight_decay=0.0, grad_clip=1e9)
        params = _tiny_params()
        grads = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 0.3, params)
        state = adamw.init(params, cfg)
        new_p, new_s = adamw.update(grads, state, params, cfg)
        # reference: first step => m=0.1g*?; m=(1-b1)g; v=(1-b2)g^2
        g = 0.3
        m = (1 - 0.9) * g
        v = (1 - 0.999) * g * g
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.999)
        expected_delta = 1e-2 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(
            np.asarray(params["b"]) - np.asarray(new_p["b"]),
            expected_delta, rtol=1e-5)

    def test_taylor_division_close_to_exact(self):
        params = _tiny_params()
        grads = jax.tree_util.tree_map(
            lambda p: jnp.asarray(np.random.default_rng(0).normal(
                size=p.shape), jnp.float32), params)
        cfg_e = adamw.AdamWConfig(division=DivisionConfig(mode="exact"))
        cfg_t = adamw.AdamWConfig(division=DivisionConfig(mode="taylor"))
        pe, _ = adamw.update(grads, adamw.init(params, cfg_e), params, cfg_e)
        pt, _ = adamw.update(grads, adamw.init(params, cfg_t), params, cfg_t)
        d = jax.tree_util.tree_map(
            lambda a, b: float(jnp.max(jnp.abs(a - b))), pe, pt)
        assert max(jax.tree_util.tree_leaves(d)) < 1e-6

    def test_grad_clip(self):
        cfg = adamw.AdamWConfig(grad_clip=0.5, lr=1.0, weight_decay=0.0)
        params = _tiny_params()
        big = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 1e3, params)
        small = jax.tree_util.tree_map(lambda p: jnp.ones_like(p) * 1e-6, params)
        pb, _ = adamw.update(big, adamw.init(params, cfg), params, cfg)
        ps, _ = adamw.update(small, adamw.init(params, cfg), params, cfg)
        # both finite; big grads were clipped (bounded step)
        for leaf in jax.tree_util.tree_leaves(pb):
            assert bool(jnp.all(jnp.isfinite(leaf)))


class TestCompression:
    def test_roundtrip_error_within_one_lsb(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
        err0 = jnp.zeros_like(g)
        deq, err = compress.quantize_roundtrip(g, err0)
        lsb = float(jnp.max(jnp.abs(g))) / 127.0
        assert float(jnp.max(jnp.abs(deq - g))) <= lsb * 0.5 + 1e-7

    def test_error_feedback_unbiased_over_time(self):
        """Accumulated dequantized sum converges to true sum (EF property)."""
        rng = np.random.default_rng(1)
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32)) * 0.01
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        T = 200
        for _ in range(T):
            deq, err = compress.quantize_roundtrip(g, err)
            acc = acc + deq
        # mean of dequantized equals g to within one final residual/T
        np.testing.assert_allclose(np.asarray(acc / T), np.asarray(g),
                                   atol=float(jnp.max(jnp.abs(g))) / 127.0)

    def test_training_with_compression_converges(self):
        """Toy regression: compressed-grad SGD matches uncompressed loss."""
        rng = np.random.default_rng(2)
        X = jnp.asarray(rng.normal(size=(256, 8)).astype(np.float32))
        w_true = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
        y = X @ w_true

        def loss(w):
            return jnp.mean((X @ w - y) ** 2)

        gfn = jax.grad(loss)
        w1 = jnp.zeros(8)
        w2 = jnp.zeros(8)
        err = jnp.zeros(8)
        for _ in range(300):
            w1 = w1 - 0.05 * gfn(w1)
            deq, err = compress.quantize_roundtrip(gfn(w2), err)
            w2 = w2 - 0.05 * deq
        assert float(loss(w2)) < 1e-3
        assert abs(float(loss(w2)) - float(loss(w1))) < 1e-3
