"""Optional-hypothesis shim: tier-1 must collect and run without the package.

With ``hypothesis`` installed (see requirements-dev.txt) this re-exports the
real thing and property tests get full search + shrinking. Without it, a
minimal fallback replays a deterministic fixed-example grid per test —
boundary values first, then seeded samples — so every property test still
*executes* in minimal environments instead of killing collection.

Usage in test modules (drop-in for the hypothesis import):

    from _hypothesis_compat import given, settings, st
"""
from __future__ import annotations

try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import functools
    import inspect
    import zlib

    import numpy as np

    # Replay at most this many fixed examples per test; hypothesis-style
    # max_examples=200 budgets are for randomized search, not fixed replay.
    _MAX_REPLAY = 24

    class _Strategy:
        def __init__(self, boundary, sample):
            self._boundary = list(boundary)
            self._sample = sample

        def examples(self, n, rng):
            out = list(self._boundary[:n])
            while len(out) < n:
                out.append(self._sample(rng))
            # Deterministic shuffle so tuples pair boundaries with
            # non-boundaries across multi-strategy @given calls.
            return [out[i] for i in rng.permutation(len(out))]

    class _St:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                [min_value, max_value, (min_value + max_value) // 2],
                lambda r: int(r.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            if min_value > 0:
                lo, hi = np.log(min_value), np.log(max_value)
                sample = lambda r: float(np.exp(r.uniform(lo, hi)))
            else:
                sample = lambda r: float(r.uniform(min_value, max_value))
            return _Strategy([min_value, max_value], sample)

        @staticmethod
        def sampled_from(seq):
            seq = list(seq)
            return _Strategy(seq, lambda r: seq[int(r.integers(len(seq)))])

        @staticmethod
        def booleans():
            return _Strategy([False, True], lambda r: bool(r.integers(2)))

    st = _St()

    def settings(max_examples: int = _MAX_REPLAY, deadline=None, **_kw):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            n = min(getattr(fn, "_compat_max_examples", _MAX_REPLAY),
                    _MAX_REPLAY)
            sig = inspect.signature(fn)
            params = list(sig.parameters.values())

            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                rng = np.random.default_rng(
                    zlib.crc32(fn.__qualname__.encode()))
                cols = [s.examples(n, rng) for s in strategies]
                for vals in zip(*cols):
                    fn(*args, *vals, **kwargs)

            # Strategies bind the rightmost params; hide them from pytest's
            # fixture resolution (inspect.signature would follow __wrapped__).
            wrapper.__signature__ = sig.replace(
                parameters=params[:len(params) - len(strategies)])
            return wrapper
        return deco
