"""Conformance gates: the paper's precision claim measured in ULPs.

Enforces (a) eq. 17 at the f32 operating point — n=2 iterations on the
24-bit seed table deliver a reciprocal within 2 ULP of the f64 oracle over
the stratified sweep; (b) Goldschmidt parity — at matched covered-term
count it lands within 1 integer ULP of the factored Taylor schedule; and
(c) the committed golden vectors (bit-exact accuracy regressions fail here).
"""
import json

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.core import goldschmidt, taylor
from repro.core.seeds import compute_segments
from repro.eval import conformance, golden, ulp


@pytest.fixture(scope="module")
def sweep_f32():
    """Stratified normal-operand sweep incl. seed-segment boundary straddles."""
    t = compute_segments(2, 24)
    strata = ulp.stratified_sweep("float32", n_log=4096, n_man=4096,
                                  boundaries=t.boundaries)
    x = np.concatenate([np.asarray(s, np.float32) for s in strata.values()])
    x64 = x.astype(np.float64)
    keep = ulp.oracle_mask(x64) & ulp.oracle_mask(
        np.divide(1.0, x64, out=np.zeros_like(x64), where=x64 != 0))
    return x[keep]


def test_paper_claim_n2_p24_within_2ulp(sweep_f32):
    """Eq. 17 gate: n=2 @ 24-bit seed => f32 reciprocal max error <= 2 ULP."""
    t = compute_segments(2, 24)
    x = jnp.asarray(sweep_f32)
    exact = 1.0 / sweep_f32.astype(np.float64)
    for schedule in ("paper", "factored"):
        r = np.asarray(taylor.reciprocal(x, t, schedule=schedule))
        errs = ulp.ulp_error(r, exact)
        assert errs.max() <= 2.0, (schedule, errs.max())
    # The factored schedule (production default) is comfortably sub-ULP.
    r = np.asarray(taylor.reciprocal(x, t, schedule="factored"))
    assert ulp.ulp_error(r, exact).max() <= 1.0


def test_goldschmidt_within_1ulp_of_factored(sweep_f32):
    """Matched covered-term count: |goldschmidt - factored| <= 1 integer ULP."""
    t = compute_segments(2, 24)
    x = jnp.asarray(sweep_f32)
    rf = np.asarray(taylor.reciprocal(x, t, schedule="factored"))
    rg = np.asarray(goldschmidt.reciprocal(
        x, t, iters=goldschmidt.iters_for_terms(2)))
    d = ulp.ulp_diff(rg, rf)
    assert d.max() <= 1, d.max()
    # And Goldschmidt itself stays within the 2-ULP paper gate.
    exact = 1.0 / sweep_f32.astype(np.float64)
    assert ulp.ulp_error(rg, exact).max() <= 2.0


def test_pallas_kernels_match_jnp_within_1ulp(sweep_f32):
    """Fused kernels and jnp twins agree to <= 1 ULP on the full sweep."""
    x = jnp.asarray(sweep_f32)
    for mode, twin in [("taylor_pallas", "taylor"),
                       ("goldschmidt_pallas", "goldschmidt")]:
        rk = np.asarray(dm.recip(x, dm.DivisionConfig(mode=mode)))
        rj = np.asarray(dm.recip(x, dm.DivisionConfig(mode=twin)))
        assert ulp.ulp_diff(rk, rj).max() <= 1, mode


def test_dial_monotone_in_ulp(sweep_f32):
    """The accuracy dial: higher (n, bits) => strictly tighter max ULP."""
    x = jnp.asarray(sweep_f32)
    exact = 1.0 / sweep_f32.astype(np.float64)
    maxes = []
    for n, p in [(1, 12), (2, 24)]:
        t = compute_segments(n, p)
        r = np.asarray(taylor.reciprocal(x, t, schedule="factored"))
        maxes.append(ulp.ulp_error(r, exact).max())
    assert maxes[0] > 4 * maxes[1], maxes   # 12-bit config is way looser


@pytest.mark.slow
def test_conformance_grid_all_modes():
    """The runner covers all five algorithm families and both dtypes,
    with a clean IEEE edge contract and a JSON-serializable report."""
    report = conformance.run_conformance(quick=True, n_log=256, n_man=256)
    modes = {c["mode"] for c in report["cells"]}
    assert {"exact", "taylor", "taylor_pallas", "goldschmidt",
            "goldschmidt_pallas", "ilm"} <= modes
    dtypes = {c["dtype"] for c in report["cells"]}
    assert {"float32", "bfloat16"} <= dtypes
    for c in report["cells"]:
        assert c["edge_failures"] == 0, c["key"]
    exact_cell = conformance.cell_lookup(report, mode="exact", op="recip",
                                         dtype="float32")
    assert exact_cell["overall"]["max_ulp"] <= 0.5 + 1e-9
    ilm_cell = conformance.cell_lookup(report, mode="ilm", op="recip",
                                       dtype="float32")
    assert ilm_cell["overall"]["max_ulp"] > 100   # genuinely ~12-bit
    json.dumps(report)                            # machine-readable
    assert conformance.format_table(report)


def test_golden_vectors_unchanged():
    """Committed golden vectors: any numerics drift fails loudly, by name."""
    assert golden.GOLDEN_PATH.exists(), (
        "golden store missing — run `python -m repro.eval.golden --generate`")
    failures = golden.check()
    assert failures == [], failures


def test_ulp_engine_selfchecks():
    """The measuring stick itself: ordered map, ulp sizes, masks."""
    a = np.float32(1.0)
    up = np.nextafter(a, np.float32(2.0))
    assert ulp.ulp_diff(np.asarray([a]), np.asarray([up]))[0] == 1
    assert ulp.ulp_diff(np.asarray([np.float32(0.0)]),
                        np.asarray([np.float32(-0.0)]))[0] == 0
    assert ulp.ulp_diff(np.asarray([np.float32(np.nan)]),
                        np.asarray([np.float32(np.nan)]))[0] == 0
    # ulp_size: 2^-23 at 1.0, constant 2^-149 through the f32 subnormals.
    assert ulp.ulp_size(np.asarray([1.0]))[0] == 2.0 ** -23
    assert ulp.ulp_size(np.asarray([1e-40]))[0] == 2.0 ** -149
    # bf16: 8 mantissa bits -> ulp(1.0) = 2^-7.
    assert ulp.ulp_size(np.asarray([1.0]), "bfloat16")[0] == 2.0 ** -7
    # oracle_mask rejects inf/nan/subnormal/overflow, keeps normals.
    m = ulp.oracle_mask(np.asarray([1.0, np.inf, np.nan, 1e-40, 1e39]))
    assert list(m) == [True, False, False, False, False]
    # error of a half-ulp-perturbed value is 0.5.
    exact = np.asarray([1.0 + 2.0 ** -24])
    got = np.asarray([np.float32(1.0)])
    err = ulp.ulp_error(got, exact)
    assert abs(err[0] - 0.5) < 1e-6
