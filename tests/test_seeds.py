"""Paper §3: PWL seed segments, error bounds, iteration counts (Table I)."""
import math

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import seeds


class TestPaperClaims:
    def test_table_i_first_boundary_exact(self):
        t = seeds.compute_segments(5, 53)
        assert abs(t.boundaries[1] - 1.09811) < 5e-6

    def test_table_i_eight_segments(self):
        t = seeds.compute_segments(5, 53)
        assert t.n_segments == 8
        assert t.boundaries[-1] >= 2.0
        # Later boundaries agree with the paper's Table I to ~0.3% (the paper
        # used its as-printed eq.19/20; ours is the tighter eq.17 recurrence).
        for ours, theirs in zip(t.boundaries[1:], seeds.PAPER_TABLE_I):
            assert abs(ours - theirs) / theirs < 0.006

    def test_single_segment_17_iterations(self):
        # paper §3: linear seed on [1,2] needs <= 17 iterations for 53 bits
        assert seeds.iterations_required(1.0, 2.0, 53) == 17

    def test_two_segments_geometric_split(self):
        # p = sqrt(ab) equalizes the two segments' error (paper §3).
        n_left = seeds.iterations_required(1.0, math.sqrt(2.0), 53)
        n_right = seeds.iterations_required(math.sqrt(2.0), 2.0, 53)
        assert n_left == n_right  # equal-error split
        # Paper claims 15; eq.17 actually gives 10 — a paper inconsistency we
        # record (EXPERIMENTS.md §Paper-validation). Both < 17 (improvement).
        assert n_left < 17

    def test_f32_table(self):
        t = seeds.compute_segments(2, 24)
        assert t.max_error_bound() <= 2**-24


class TestSeedMath:
    def test_optimal_p_minimizes_total_error(self):
        # E_total(p) from eq.14; optimum at p=(a+b)/2 (eq.15)
        a, b = 1.0, 2.0
        def e_total(p):
            return (np.log(b / a) + (b**2 - a**2) / (2 * p**2)
                    - 2 * (b - a) / p)
        p_opt = (a + b) / 2
        for p in [p_opt * 0.9, p_opt * 1.1, p_opt * 0.99, p_opt * 1.01]:
            assert e_total(p_opt) <= e_total(p) + 1e-15

    @given(st.floats(1.0, 1.9), st.floats(0.01, 0.5), st.integers(0, 8))
    @settings(max_examples=60, deadline=None)
    def test_error_bound_holds(self, a, width, n):
        """Eq. 17 is a true upper bound: measured series error <= bound."""
        b = a + width
        slope, intercept = seeds.linear_seed_coeffs(a, b)
        xs = np.linspace(a, b, 500)
        y0 = slope * xs + intercept
        m = 1.0 - xs * y0
        # series approx of 1/x: y0 * sum_{k<=n} m^k; exact error y0*m^(n+1)/(1-m)
        acc = np.zeros_like(xs)
        for k in range(n + 1):
            acc += m**k
        approx = y0 * acc
        err = np.abs(1.0 / xs - approx)
        bound = seeds.seed_error_bound(a, b, n)
        # + ~9 ulp f64 slack: the bound is on exact arithmetic, the series
        # evaluation itself rounds at a few 1e-16
        assert np.all(err <= bound * (1 + 1e-6) + 1e-15)

    @given(st.integers(1, 8), st.integers(8, 40))
    @settings(max_examples=30, deadline=None)
    def test_segments_meet_precision(self, n, prec):
        t = seeds.compute_segments(n, prec)
        assert t.max_error_bound() <= 2.0**-prec * (1 + 1e-9)
        # segments tile [1,2] without gaps
        assert t.boundaries[0] == 1.0
        assert t.boundaries[-1] >= 2.0
        assert np.all(np.diff(t.boundaries) > 0)

    def test_rsqrt_table(self):
        t = seeds.rsqrt_seed_table(16)
        assert t.precision_bits >= 10  # seed good enough for 2 Newton steps
        xs = np.linspace(0.5, 1.999, 1000)
        y = t.seed(xs)
        assert np.max(np.abs(y * np.sqrt(xs) - 1.0)) < 2.0**-t.precision_bits * 1.01
