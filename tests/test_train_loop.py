"""Training loop: convergence, kill->resume determinism, straggler watchdog."""
import os

import numpy as np
import pytest

import jax

from repro.configs import get_smoke_config
from repro.data import DataConfig
from repro.train import fault
from repro.train.loop import LoopConfig, run


def _data_cfg(cfg, seed=1):
    return DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=8, seed=seed)


def test_loss_decreases():
    cfg = get_smoke_config("paper_fpdiv")
    out = run(cfg, LoopConfig(total_steps=25, log_every=100), _data_cfg(cfg),
              log=lambda s: None)
    l = out["losses"]
    assert l[-1] < l[0] - 0.3, f"no learning: {l[0]:.3f} -> {l[-1]:.3f}"


def test_kill_resume_bit_identical(tmp_path):
    cfg = get_smoke_config("paper_fpdiv")
    dc = _data_cfg(cfg)
    d_int = str(tmp_path / "interrupted")
    d_ref = str(tmp_path / "straight")
    with pytest.raises(fault.FailureInjector.Injected):
        run(cfg, LoopConfig(total_steps=14, ckpt_every=5, ckpt_dir=d_int,
                            log_every=100), dc,
            injector=fault.FailureInjector(fail_at_step=8), log=lambda s: None)
    resumed = run(cfg, LoopConfig(total_steps=14, ckpt_every=5, ckpt_dir=d_int,
                                  log_every=100), dc, log=lambda s: None)
    straight = run(cfg, LoopConfig(total_steps=14, ckpt_every=5, ckpt_dir=d_ref,
                                   log_every=100), dc, log=lambda s: None)
    diffs = jax.tree_util.tree_map(
        lambda a, b: float(jax.numpy.max(jax.numpy.abs(
            a.astype("float32") - b.astype("float32")))),
        resumed["state"].params, straight["state"].params)
    assert max(jax.tree_util.tree_leaves(diffs)) == 0.0


def test_straggler_watchdog_detects_slow_step():
    wd = fault.StragglerWatchdog(threshold=3.0, warmup=3)
    for i in range(10):
        wd.observe(i, 0.1)
    ev = wd.observe(10, 1.0)  # 10x slower
    assert ev is not None and ev.step == 10
    # EWMA not poisoned by the straggler
    assert wd.ewma < 0.2
    assert wd.observe(11, 0.1) is None


def test_preemption_guard_restores_handlers():
    import signal

    before = signal.getsignal(signal.SIGTERM)
    with fault.PreemptionGuard() as g:
        assert not g.preempted
        os.kill(os.getpid(), signal.SIGTERM)
        assert g.preempted
    assert signal.getsignal(signal.SIGTERM) is before
