"""op=div conformance gates: the exponent-separated divide datapath.

What PR 1's grid exposed: divide composed as ``a * recip(b)`` measured
1.6e7 max ULP on the full-exponent sweep because the intermediate
reciprocal under/overflows even when a/b is representable. This module
gates the fix:

  (a) the eq. 17-style hard gate — taylor (paper + factored schedules) at
      n=2 @ 24-bit and goldschmidt divide each land within 2 ULP of the f64
      oracle over the full-exponent div sweep, ratio-straddling corpora
      included;
  (b) the fused Pallas divide kernels agree with their jnp twins;
  (c) IEEE special-value tables (±0/±inf/nan sign rules) in every mode,
      plus the subnormal FTZ edge class per datapath;
  (d) property-based ratio-straddling pairs with pinned replay examples;
  (e) mode="goldschmidt_pallas" divide dispatches to the fused joint-N/D
      kernel, never the recip+multiply composition;
  (f) gradients through the frexp/ldexp datapath stay analytic.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from _hypothesis_compat import given, settings, st
from repro.core import division_modes as dm
from repro.core import goldschmidt, taylor
from repro.core.seeds import compute_segments
from repro.eval import golden, ulp

JNP_MODES = ["exact", "taylor", "goldschmidt", "ilm"]
PALLAS_MODES = ["taylor_pallas", "goldschmidt_pallas"]


@pytest.fixture(scope="module")
def div_sweep_f32():
    """Full stratified div pair sweep, masked to oracle-valid normal lanes."""
    t = compute_segments(2, 24)
    pairs = ulp.div_sweep("float32", n_log=4096, n_man=4096,
                          boundaries=t.boundaries)
    a = np.concatenate([np.asarray(p[0], np.float32) for p in pairs.values()])
    b = np.concatenate([np.asarray(p[1], np.float32) for p in pairs.values()])
    a64, b64 = a.astype(np.float64), b.astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        exact = a64 / b64
    mask = (ulp.oracle_mask(exact) & ulp.cliff_guard(exact)
            & ulp.oracle_mask(a64) & ulp.oracle_mask(b64))
    return a[mask], b[mask], exact[mask]


class TestHardGate:
    def test_taylor_divide_n2_p24_within_2ulp(self, div_sweep_f32):
        """Eq. 17-style gate: both Taylor schedules <= 2 ULP on the div sweep
        (was 1.6e7 as a*recip(b)). The Markstein-corrected final multiply
        actually delivers a near-correctly-rounded quotient (<= 1 ULP)."""
        a, b, exact = div_sweep_f32
        t = compute_segments(2, 24)
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        for sched in ("paper", "factored"):
            q = np.asarray(taylor.divide(aj, bj, t, schedule=sched))
            errs = ulp.ulp_error(q, exact)
            assert errs.max() <= 2.0, (sched, errs.max())
            assert errs.max() <= 1.0, (sched, errs.max())

    def test_goldschmidt_divide_within_2ulp(self, div_sweep_f32):
        """Joint N/D refinement stays within the same 2-ULP gate."""
        a, b, exact = div_sweep_f32
        t = compute_segments(2, 24)
        q = np.asarray(goldschmidt.divide(
            jnp.asarray(a), jnp.asarray(b), t,
            iters=goldschmidt.iters_for_terms(2)))
        errs = ulp.ulp_error(q, exact)
        assert errs.max() <= 2.0, errs.max()

    def test_fused_kernels_match_jnp_twins(self, div_sweep_f32):
        """Pallas divide kernels agree with the jit'd jnp twins <= 1 int ULP
        (jit matters: XLA's FMA contraction moves the eager twin ~1 ULP)."""
        a, b, _ = div_sweep_f32
        aj, bj = jnp.asarray(a), jnp.asarray(b)
        t = compute_segments(2, 24)
        twins = {
            "taylor_pallas": jax.jit(
                lambda x, y: taylor.divide(x, y, t, schedule="factored")),
            "goldschmidt_pallas": jax.jit(
                lambda x, y: goldschmidt.divide(
                    x, y, t, iters=goldschmidt.iters_for_terms(2))),
        }
        for mode, twin in twins.items():
            qk = np.asarray(dm.div(aj, bj, dm.DivisionConfig(mode=mode)))
            qj = np.asarray(twin(aj, bj))
            assert ulp.ulp_diff(qk, qj).max() <= 1, mode

    def test_divide_golden_vectors_unchanged(self):
        """Committed op=div golden store: numerics drift fails by cell name."""
        assert golden.DIVIDE_PATH.exists(), (
            "divide golden store missing — run "
            "`python -m repro.eval.golden --generate --store divide`")
        failures = golden.check_divide()
        assert failures == [], failures


# ---------------------------------------------------------- special values

# (a, b, expected) rows where the IEEE outcome is fixed by the operands.
# 'expected' is a string class so signed zeros are distinguishable.
SPECIAL_ROWS = [
    (1.0, 0.0, "+inf"), (-1.0, 0.0, "-inf"),
    (1.0, -0.0, "-inf"), (-1.0, -0.0, "+inf"),
    (0.0, 0.0, "nan"), (-0.0, -0.0, "nan"), (0.0, -0.0, "nan"),
    (np.inf, np.inf, "nan"), (-np.inf, np.inf, "nan"),
    (np.inf, -np.inf, "nan"), (-np.inf, -np.inf, "nan"),
    (np.inf, 2.0, "+inf"), (np.inf, -2.0, "-inf"),
    (-np.inf, 2.0, "-inf"), (-np.inf, -2.0, "+inf"),
    (np.inf, 0.0, "+inf"), (-np.inf, 0.0, "-inf"), (np.inf, -0.0, "-inf"),
    (2.0, np.inf, "+0"), (-2.0, np.inf, "-0"),
    (2.0, -np.inf, "-0"), (-2.0, -np.inf, "+0"),
    (0.0, np.inf, "+0"), (-0.0, np.inf, "-0"), (0.0, -np.inf, "-0"),
    (0.0, 2.0, "+0"), (0.0, -2.0, "-0"),
    (-0.0, 2.0, "-0"), (-0.0, -2.0, "+0"),
    (np.nan, 2.0, "nan"), (2.0, np.nan, "nan"),
    (np.nan, 0.0, "nan"), (np.inf, np.nan, "nan"), (np.nan, np.nan, "nan"),
]


def _classify(v: float) -> str:
    if np.isnan(v):
        return "nan"
    if np.isinf(v):
        return "+inf" if v > 0 else "-inf"
    if v == 0:
        return "-0" if np.signbit(v) else "+0"
    return "finite"


@pytest.mark.parametrize("mode", JNP_MODES + PALLAS_MODES)
def test_div_ieee_special_value_table(mode):
    """±0/±inf/nan sign rules hold in every mode, jnp and fused alike."""
    a = jnp.asarray([r[0] for r in SPECIAL_ROWS], jnp.float32)
    b = jnp.asarray([r[1] for r in SPECIAL_ROWS], jnp.float32)
    q = np.asarray(dm.div(a, b, dm.DivisionConfig(mode=mode)))
    for (av, bv, want), got in zip(SPECIAL_ROWS, q):
        assert _classify(float(got)) == want, (mode, av, bv, float(got))


@pytest.mark.parametrize("mode", PALLAS_MODES)
def test_div_subnormal_ftz_kernel_modes(mode):
    """Fused kernels run FTZ: subnormal operands act as zeros, subnormal
    quotients flush to signed zero (the hardware unit's contract)."""
    cfg = dm.DivisionConfig(mode=mode)
    sub = np.float32(2.0 ** -130)
    # b subnormal -> treated as 0 -> x/0 = inf.
    q = np.asarray(dm.div(jnp.asarray([1.0, -1.0], jnp.float32),
                          jnp.asarray([sub, sub], jnp.float32), cfg))
    assert np.isposinf(q[0]) and np.isneginf(q[1]), (mode, q)
    # a subnormal -> treated as 0 -> 0/y = signed 0.
    q = np.asarray(dm.div(jnp.asarray([sub, -sub], jnp.float32),
                          jnp.asarray([2.0, 2.0], jnp.float32), cfg))
    assert q[0] == 0 and not np.signbit(q[0]), (mode, q)
    assert q[1] == 0 and np.signbit(q[1]), (mode, q)
    # Subnormal quotient (2^-100 / 2^100 = 2^-200) -> signed 0.
    q = np.asarray(dm.div(jnp.asarray([2.0 ** -100, -(2.0 ** -100)], jnp.float32),
                          jnp.asarray([2.0 ** 100, 2.0 ** 100], jnp.float32), cfg))
    assert q[0] == 0 and not np.signbit(q[0]), (mode, q)
    assert q[1] == 0 and np.signbit(q[1]), (mode, q)


@pytest.mark.parametrize("mode", ["taylor", "taylor_pallas",
                                  "goldschmidt", "goldschmidt_pallas"])
def test_div_mixed_dtype_promotes(mode):
    """bf16/f32 mixed operands promote to f32 (as a * recip(b) did) —
    the exponent-separated wrappers must not demote to a's dtype."""
    cfg = dm.DivisionConfig(mode=mode)
    a = jnp.asarray([1.0, 10.0], jnp.bfloat16)
    b = jnp.asarray([3.0, 7.0], jnp.float32)
    q = dm.div(a, b, cfg)
    assert q.dtype == jnp.float32, (mode, q.dtype)
    np.testing.assert_allclose(np.asarray(q), [1 / 3, 10 / 7], rtol=1e-6)
    q = dm.div(b, a, cfg)
    assert q.dtype == jnp.float32, (mode, q.dtype)


@pytest.mark.parametrize("mode", ["taylor", "goldschmidt"])
def test_div_subnormal_gradual_exact_jnp_modes(mode):
    """The jnp twins' subnormal contract since the bit-level datapath:
    quotients *below* the subnormal range still round to signed zero, but
    subnormal operands are handled exactly under the default gradual
    policy (PR 2 had to mask them as a degraded frexp class)."""
    cfg = dm.DivisionConfig(mode=mode)
    q = np.asarray(dm.div(
        jnp.asarray([2.0 ** -100, -(2.0 ** -100)], jnp.float32),
        jnp.asarray([2.0 ** 100, 2.0 ** 100], jnp.float32), cfg))
    assert q[0] == 0 and not np.signbit(q[0]), (mode, q)      # 2^-200 -> +0
    assert q[1] == 0 and np.signbit(q[1]), (mode, q)          # -> -0
    sub = np.float32(2.0 ** -127)
    q = np.asarray(dm.div(jnp.asarray([sub, 1.0], jnp.float32),
                          jnp.asarray([1.0, sub], jnp.float32), cfg))
    np.testing.assert_array_equal(q, [2.0 ** -127, 2.0 ** 127])  # exact now


# ------------------------------------------------- property-based straddles

# Pinned replays of the class PR 1 exposed: quotient representable while
# the intermediate reciprocal is subnormal (b > 2^126) or the composed
# product loses the low bits.
PINNED_PAIRS = [
    (2.0 ** 100, 2.0 ** 127),       # 1/b subnormal; a/b = 2^-27
    (2.0 ** 120, 2.0 ** 127),       # 1/b subnormal; a/b = 2^-7
    (-(2.0 ** 90), 2.0 ** 126.5),   # sign through the straddle
    (3.0e38, 2.9e38),               # both near overflow; a/b ~ 1.03
    (2.0 ** -120, 2.0 ** -126),     # both near underflow; a/b = 2^6
    (1.5, 2.0 ** 127),              # quotient itself near the FTZ cliff
]


@pytest.mark.parametrize("mode,schedule", [
    ("taylor", "paper"), ("taylor", "factored"),
    ("taylor_pallas", "factored"), ("goldschmidt", "-"),
    ("goldschmidt_pallas", "-"),
])
def test_pinned_ratio_straddle_pairs(mode, schedule):
    sched = schedule if schedule != "-" else "factored"
    cfg = dm.DivisionConfig(mode=mode, schedule=sched)
    a = np.asarray([p[0] for p in PINNED_PAIRS], np.float32)
    b = np.asarray([p[1] for p in PINNED_PAIRS], np.float32)
    q = np.asarray(dm.div(jnp.asarray(a), jnp.asarray(b), cfg))
    exact = a.astype(np.float64) / b.astype(np.float64)
    errs = ulp.ulp_error(q, exact)
    assert errs.max() <= 2.0, (mode, schedule, errs.max())


@settings(max_examples=100, deadline=None)
@given(st.floats(1.0, 1.999), st.floats(1.0, 1.999),
       st.integers(-120, 0), st.integers(121, 126))
def test_prop_quotient_representable_intermediate_underflow(ma, mb, eq, eb):
    """Random (a, b) with b in [2^121, 2^127) — the a*recip(b) death zone —
    and a chosen so the quotient is a mid-range normal. Every divide mode
    must land within 2 ULP of the f64 oracle."""
    b = np.float32(mb * 2.0 ** eb)
    a = np.float32(ma * 2.0 ** (eq + eb))
    exact = float(a) / float(b)          # f64, exactly representable ratio
    aj = jnp.asarray([a], jnp.float32)
    bj = jnp.asarray([b], jnp.float32)
    for mode, sched in [("taylor", "paper"), ("taylor", "factored"),
                        ("goldschmidt", "-")]:
        cfg = dm.DivisionConfig(
            mode=mode, schedule=sched if sched != "-" else "factored")
        q = float(np.asarray(dm.div(aj, bj, cfg))[0])
        err = ulp.ulp_error(np.asarray([q]), np.asarray([exact]))
        assert err.max() <= 2.0, (mode, sched, a, b, q, exact)


# --------------------------------------------------------- kernel dispatch

def test_goldschmidt_pallas_divide_uses_fused_kernel(monkeypatch):
    """mode="goldschmidt_pallas" divide must lower to the fused joint-N/D
    kernel — never the recip kernel + multiply composition."""
    from repro.kernels import ops as kops

    schedules = []
    real_divide = kops.tsdiv_divide

    def spy(a, b, n_iters=2, precision_bits=24, schedule="factored"):
        schedules.append(schedule)
        return real_divide(a, b, n_iters, precision_bits, schedule)

    def forbidden(*args, **kwargs):
        raise AssertionError("divide fell back to recip+multiply")

    monkeypatch.setattr(kops, "tsdiv_divide", spy)
    monkeypatch.setattr(kops, "tsdiv_recip", forbidden)
    a = jnp.full((8, 128), 6.0, jnp.float32)
    b = jnp.full((8, 128), 3.0, jnp.float32)
    q = dm.div(a, b, dm.DivisionConfig(mode="goldschmidt_pallas"))
    np.testing.assert_allclose(np.asarray(q), 2.0, rtol=1e-6)
    assert schedules == ["goldschmidt"]
    schedules.clear()
    q = dm.div(a, b, dm.DivisionConfig(mode="taylor_pallas"))
    np.testing.assert_allclose(np.asarray(q), 2.0, rtol=1e-6)
    assert schedules == ["factored"]


# --------------------------------------------------------------- gradients

@pytest.mark.parametrize("mode", ["taylor", "taylor_pallas",
                                  "goldschmidt", "goldschmidt_pallas"])
def test_div_gradcheck_analytic(mode):
    """d(a/b) = (1/b, -a/b^2): the frexp/ldexp datapath must not zero the
    cotangent (attach_grad / custom_vjp supply the analytic gradient)."""
    cfg = dm.DivisionConfig(mode=mode)
    ga, gb = jax.grad(lambda x, y: dm.div(x, y, cfg).sum(), argnums=(0, 1))(
        jnp.float32(6.0), jnp.float32(3.0))
    assert abs(float(ga) - 1 / 3) < 1e-5, (mode, ga)
    assert abs(float(gb) + 2 / 3) < 1e-5, (mode, gb)
    # Vector case across a spread of exponents.
    a = jnp.asarray([2.0 ** -40, 3.0, -(2.0 ** 40)], jnp.float32)
    b = jnp.asarray([2.0 ** 20, -7.0, 2.0 ** -20], jnp.float32)
    ga, gb = jax.grad(lambda x, y: dm.div(x, y, cfg).sum(), argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), 1 / np.asarray(b), rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(gb), -np.asarray(a) / np.asarray(b) ** 2, rtol=1e-5)


@pytest.mark.parametrize("mode", ["taylor", "goldschmidt"])
def test_div_grad_extreme_exponents_jnp(mode):
    """The jnp twins keep analytic gradients even where 1/b is subnormal
    (the gradient lane degrades gracefully, the primal never does)."""
    cfg = dm.DivisionConfig(mode=mode)
    a0, b0 = jnp.float32(2.0 ** 100), jnp.float32(2.0 ** 110)
    ga, gb = jax.grad(lambda x, y: dm.div(x, y, cfg).sum(), argnums=(0, 1))(
        a0, b0)
    np.testing.assert_allclose(float(ga), 2.0 ** -110, rtol=1e-5)
    np.testing.assert_allclose(float(gb), -(2.0 ** -120), rtol=1e-5)


@pytest.mark.parametrize("mode", ["taylor", "taylor_pallas",
                                  "goldschmidt", "goldschmidt_pallas"])
def test_div_grad_edges_do_not_poison(mode):
    """Gradients at IEEE edge operands are finite (masked), never nan."""
    cfg = dm.DivisionConfig(mode=mode)
    a = jnp.asarray([1.0, 0.0, np.inf], jnp.float32)
    b = jnp.asarray([0.0, 0.0, 2.0], jnp.float32)
    ga, gb = jax.grad(
        lambda x, y: jnp.sum(jnp.where(jnp.isfinite(dm.div(x, y, cfg)),
                                       dm.div(x, y, cfg), 0.0)),
        argnums=(0, 1))(a, b)
    assert np.all(np.isfinite(np.asarray(ga))), (mode, ga)
    assert np.all(np.isfinite(np.asarray(gb))), (mode, gb)
