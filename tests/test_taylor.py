"""Paper §2 + §6: Taylor-series reciprocal — oracle precision, schedules, edges."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax
import jax.numpy as jnp

from repro.core import seeds, taylor


class TestOracle53Bit:
    """Validates the paper's headline claim: 8 segments + n=5 -> 53-bit recip."""

    def test_paper_table_n5(self, rng):
        t = seeds.compute_segments(5, 53)
        x = rng.uniform(1.0, 2.0, 100_000)
        r = taylor.reciprocal_np(x, t, schedule="paper")
        # algorithmic error <= 2^-53; f64 evaluation adds <= ~4 ulp rounding
        assert np.max(np.abs(r * x - 1.0)) < 2**-50

    def test_error_tracks_eq17_bound_at_low_n(self, rng):
        """Where the bound is far above f64 eps, measured error respects it
        and is within 100x of it (the bound is not vacuous)."""
        t = seeds.compute_segments(5, 53)
        x = rng.uniform(1.0, 2.0, 100_000)
        for n in (1, 2, 3):
            r = taylor.reciprocal_np(x, t, n_iters=n, schedule="paper")
            err = np.max(np.abs(r * x - 1.0))
            bound = t.max_error_bound(n)
            assert err <= bound * (1 + 1e-6)
            assert err > bound / 100

    def test_factored_at_least_as_accurate(self, rng):
        t = seeds.compute_segments(5, 53)
        x = rng.uniform(1.0, 2.0, 20_000)
        for n in (1, 2, 3):
            e_paper = np.max(np.abs(
                taylor.reciprocal_np(x, t, n_iters=n, schedule="paper") * x - 1))
            e_fact = np.max(np.abs(
                taylor.reciprocal_np(x, t, n_iters=n, schedule="factored") * x - 1))
            assert e_fact <= e_paper * (1 + 1e-9)

    def test_full_range_with_exponents(self, rng):
        t = seeds.compute_segments(5, 53)
        x = rng.uniform(-1e30, 1e30, 50_000)
        x = x[np.abs(x) > 1e-30]
        r = taylor.reciprocal_np(x, t)
        assert np.max(np.abs(r * x - 1.0)) < 2**-50

    def test_divide(self, rng):
        a = rng.normal(size=10_000) * 100
        b = rng.uniform(0.5, 100, 10_000)
        q = taylor.divide_np(a, b)
        assert np.max(np.abs(q - a / b) / np.abs(a / b + 1e-30)) < 2**-49


class TestJnpF32:
    def test_f32_default_accuracy(self, rng):
        x = jnp.asarray(rng.uniform(0.01, 1000, 50_000), jnp.float32)
        r = jax.jit(taylor.reciprocal)(x)
        rel = np.abs(np.asarray(r) * np.asarray(x) - 1.0)
        assert rel.max() < 2**-21  # ~4 ulp of f32 + algorithmic 2^-24

    def test_bf16(self, rng):
        t = seeds.compute_segments(1, 10)
        x = jnp.asarray(rng.uniform(0.1, 10, 4096), jnp.bfloat16)
        r = taylor.reciprocal(x, t)
        rel = np.abs(np.asarray(r, np.float32) * np.asarray(x, np.float32) - 1)
        assert rel.max() < 0.02  # bf16 has 8 mantissa bits

    def test_edges(self):
        x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, -2.0],
                        jnp.float32)
        r = np.asarray(taylor.reciprocal(x))
        assert np.isposinf(r[0]) and np.isneginf(r[1])
        assert r[2] == 0.0 and r[3] == 0.0
        assert np.signbit(r[3]) and not np.signbit(r[2])
        assert np.isnan(r[4])
        assert abs(r[5] - 1.0) < 1e-6 and abs(r[6] + 0.5) < 1e-6

    def test_grad(self):
        g = jax.grad(lambda v: taylor.reciprocal(v).sum())(jnp.float32(2.0))
        assert abs(float(g) + 0.25) < 1e-5

    def test_rsqrt(self, rng):
        x = jnp.asarray(rng.uniform(1e-6, 1e6, 50_000), jnp.float32)
        r = jax.jit(taylor.rsqrt)(x)
        rel = np.abs(np.asarray(r) * np.sqrt(np.asarray(x)) - 1.0)
        assert rel.max() < 1e-5

    def test_rsqrt_oracle(self, rng):
        x = rng.uniform(1e-8, 1e8, 50_000)
        r = taylor.rsqrt_np(x, newton_iters=3)
        assert np.max(np.abs(r * np.sqrt(x) - 1.0)) < 1e-11


@given(st.floats(1e-20, 1e20), st.integers(1, 6),
       st.sampled_from(["paper", "factored"]))
@settings(max_examples=80, deadline=None)
def test_property_recip_error_bound(x, n, schedule):
    """For any normal x, n, schedule: |r*x - 1| <= table bound + f64 rounding."""
    t = seeds.compute_segments(n, 53)
    r = float(taylor.reciprocal_np(np.asarray([x]), t, schedule=schedule)[0])
    assert abs(r * x - 1.0) <= t.max_error_bound() + 2**-48
