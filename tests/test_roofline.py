"""Roofline machinery: HLO collective parsing, wire factors, pod detection."""
import numpy as np
import pytest

from repro.launch import roofline as rl


HLO = """
ENTRY %main {
  %ar = f32[4,1024]{1,0} all-reduce(%convert_bitcast_fusion.3), replica_groups=[64,4]<=[256], to_apply=%add
  %ag = bf16[8,2048]{1,0} all-gather(%p0), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %rs = f32[2,512]{1,0} reduce-scatter(%fusion.9), replica_groups=[32,16]<=[512], to_apply=%add
  %cp = bf16[16,128]{1,0} collective-permute(%p1), source_target_pairs={{0,1}}
  %a2a = f32[4,4096]{1,0} all-to-all(%p2), replica_groups=[2,256]<=[2,256]T(1,0), dimensions={0}
"""


class TestParse:
    def test_counts_and_factors(self):
        out = rl.parse_collectives(HLO, 512, pod_size=256)
        ops = {o["op"]: o for o in out["ops"]}
        # all-reduce f32 4x1024 = 16384B, g=4 -> wire 2*(3/4)*16384
        assert ops["all-reduce"]["bytes"] == 4 * 1024 * 4
        assert abs(ops["all-reduce"]["wire_bytes"] - 1.5 * 16384) < 1
        # CPU-upcast detection: convert-fed f32 reduction halves on TPU
        assert ops["all-reduce"]["cpu_upcast"]
        assert abs(ops["all-reduce"]["wire_bytes_tpu"]
                   - 0.75 * 16384) < 1
        # all-gather bf16, g=4 -> (3/4) * bytes, no upcast
        assert not ops["all-gather"]["cpu_upcast"]
        assert abs(ops["all-gather"]["wire_bytes"]
                   - 0.75 * 8 * 2048 * 2) < 1
        # permute factor 1
        assert ops["collective-permute"]["wire_bytes"] == 16 * 128 * 2

    def test_pod_crossing_iota_transpose(self):
        """[2,256]<=[2,256]T(1,0): 2 groups of 256 interleaving pods — DCN."""
        out = rl.parse_collectives(HLO, 512, pod_size=256)
        ops = {o["op"]: o for o in out["ops"]}
        assert ops["all-to-all"]["cross_pod"]
        assert ops["all-to-all"]["group"] == 256
        # the canonical pod all-reduce form: [256,2]<=[2,256]T(1,0)
        pod_ar = ("  %x = f32[16]{0} all-reduce(%p), "
                  "replica_groups=[256,2]<=[2,256]T(1,0), to_apply=%add")
        o2 = rl.parse_collectives(pod_ar, 512, pod_size=256)["ops"][0]
        assert o2["group"] == 2 and o2["cross_pod"]
        # in-pod groups stay ICI
        assert not ops["all-reduce"]["cross_pod"]
        assert not ops["all-gather"]["cross_pod"]
        assert out["dcn_bytes"] > 0 and out["ici_bytes"] > 0

    def test_explicit_groups(self):
        out = rl.parse_collectives(HLO, 512, pod_size=None)
        ops = {o["op"]: o for o in out["ops"]}
        assert ops["all-gather"]["group"] == 4


class TestRoofline:
    def test_terms_and_bound(self):
        r = rl.Roofline(flops=197e12, bytes_accessed=819e9 * 2,
                        ici_bytes=50e9 * 0.5, dcn_bytes=0.0,
                        model_flops=98.5e12)
        assert abs(r.t_compute - 1.0) < 1e-9
        assert abs(r.t_memory - 2.0) < 1e-9
        assert abs(r.t_collective - 0.5) < 1e-9
        assert r.bound == "memory"
        assert abs(r.t_step - 2.0) < 1e-9
        assert abs(r.mfu - 0.25) < 1e-9
        assert abs(r.flops_efficiency - 0.5) < 1e-9

    def test_model_flops(self):
        # 6ND train, 2ND inference
        assert rl.model_flops_per_device(1e9, 1e6, 256, "train") == \
            pytest.approx(6e15 / 256)
        assert rl.model_flops_per_device(1e9, 128, 256, "inference") == \
            pytest.approx(2 * 1e9 * 128 / 256)


def test_memmodel_levers():
    """The HBM model responds to its physical levers in the right direction."""
    import jax
    from repro.configs import get_config, LM_SHAPES
    from repro.launch import memmodel

    class FakeMesh:
        shape = {"data": 16, "model": 16}

    cfg = get_config("llama3_8b")
    shape = LM_SHAPES["train_4k"]
    base = memmodel.hbm_traffic(cfg, shape, FakeMesh(), n_micro=4)
    fused = memmodel.hbm_traffic(cfg, shape, FakeMesh(), n_micro=4,
                                 fused_attention=True)
    assert fused["score_bytes"] == 0.0
    assert fused["total_bytes"] < base["total_bytes"]
    # decode: cache dominates
    dec = memmodel.hbm_traffic(cfg, LM_SHAPES["decode_32k"], FakeMesh())
    assert dec["cache_bytes"] > dec["activation_bytes"]
    assert dec["grads_bytes"] == 0.0
