"""End-to-end behaviour tests: serving engine, examples, dry-run subprocess."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serving import ServingEngine


def test_serving_engine_generates():
    cfg = get_smoke_config("paper_fpdiv")
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServingEngine(cfg, params, max_len=128)
    out = engine.generate(list(range(1, 17)), max_new=8)
    assert len(out) == 8
    assert all(0 <= t < cfg.vocab for t in out)


def test_serving_batched_matches_single():
    """Static batching: a batch of identical prompts decodes identically to
    the single-request path (greedy, deterministic)."""
    cfg = get_smoke_config("paper_fpdiv")
    params = init_params(cfg, jax.random.PRNGKey(3))
    engine = ServingEngine(cfg, params, max_len=96)
    single = engine.generate(list(range(1, 17)), max_new=6)
    batch = engine.generate_batch([list(range(1, 17))] * 3, max_new=6)
    assert all(b == single for b in batch)


def test_serving_greedy_deterministic():
    cfg = get_smoke_config("tinyllama_1_1b")
    params = init_params(cfg, jax.random.PRNGKey(1))
    engine = ServingEngine(cfg, params, max_len=64)
    a = engine.generate([5, 6, 7, 8], max_new=6)
    b = engine.generate([5, 6, 7, 8], max_new=6)
    assert a == b


def _run(cmd, timeout=900):
    return subprocess.run(
        cmd, capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo")


def test_quickstart_example():
    r = _run([sys.executable, "examples/quickstart.py"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "reciprocal" in r.stdout


@pytest.mark.slow
def test_dryrun_single_cell_subprocess(tmp_path):
    """The multi-pod deliverable, smoke-sized: one full 512-device cell."""
    r = _run([sys.executable, "-m", "repro.launch.dryrun",
              "--arch", "whisper_tiny", "--shape", "decode_32k",
              "--mesh", "multi", "--out", str(tmp_path)], timeout=1800)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "[ok]" in r.stdout
