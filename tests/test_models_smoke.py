"""Per-arch smoke tests: reduced configs, one forward + one train step on CPU.

Asserts output shapes, finite logits, finite loss, finite & nonzero grads.
Full configs are exercised only via the dry-run (abstract, no allocation) —
here we also validate their *abstract* param counts against the published
sizes.
"""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import forward, init_params, param_count
from repro.models.params import active_param_count
from repro.optim import adamw
from repro.train import step as ts

ARCHS = [a for a in ARCH_IDS]

EXPECTED_PARAMS_B = {
    "mamba2_780m": (0.78, 0.05),
    "granite_8b": (8.26, 0.3),
    "llama3_8b": (8.03, 0.3),
    "gemma3_12b": (11.8, 0.5),
    "tinyllama_1_1b": (1.10, 0.05),
    "llava_next_mistral_7b": (7.24, 0.3),
    "whisper_tiny": (0.041, 0.01),
    "jamba_1_5_large": (397.6, 5.0),
    "moonshot_v1_16b_a3b": (28.4, 1.0),   # 48L pinned by the assignment
    "deepseek_moe_16b": (16.4, 0.6),
    "paper_fpdiv": (0.134, 0.02),
}

EXPECTED_ACTIVE_B = {
    "jamba_1_5_large": (93.2, 2.0),
    "deepseek_moe_16b": (2.83, 0.2),
    "moonshot_v1_16b_a3b": (4.8, 0.3),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    n = param_count(cfg) / 1e9
    want, tol = EXPECTED_PARAMS_B[arch]
    assert abs(n - want) < tol, f"{arch}: {n:.3f}B vs expected {want}B"
    if arch in EXPECTED_ACTIVE_B:
        na = active_param_count(cfg) / 1e9
        want_a, tol_a = EXPECTED_ACTIVE_B[arch]
        assert abs(na - want_a) < tol_a


def _batch_for(cfg, key, B=2, S=32):
    batch = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab)}
    if cfg.is_encoder_decoder:
        batch["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    elif cfg.embed_inputs:
        batch["embeds"] = jax.random.normal(key, (B, S, cfg.d_model),
                                            jnp.float32)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_and_finite(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    batch = _batch_for(cfg, key, B, S)
    kw = {k: v for k, v in batch.items() if k != "labels"}
    logits, cache, aux = forward(cfg, params, mode="train", **kw)
    assert logits.shape == (B, S, cfg.vocab)
    assert cache is None
    assert bool(jnp.all(jnp.isfinite(logits)))
    if cfg.n_experts:
        assert float(aux) > 0.0  # router aux loss is live


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    opt_cfg = adamw.AdamWConfig(division=cfg.division)
    state = ts.init_state(cfg, params, opt_cfg)
    batch = _batch_for(cfg, key)
    new_state, metrics = jax.jit(
        lambda s, b: ts.train_step(cfg, opt_cfg, s, b, n_micro=2))(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params actually moved
    moved = jax.tree_util.tree_map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        state.params, new_state.params)
    assert max(jax.tree_util.tree_leaves(moved)) > 0
    assert int(new_state.step) == 1


def test_division_mode_exact_vs_taylor_close():
    """Same model, exact vs taylor division: logits agree to f32-kernel level."""
    cfg = get_smoke_config("paper_fpdiv")
    from repro.core.division_modes import DivisionConfig

    cfg_exact = dataclasses.replace(cfg, division=DivisionConfig(mode="exact"))
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    lt, _, _ = forward(cfg, params, tokens=toks, mode="train")
    le, _, _ = forward(cfg_exact, params, tokens=toks, mode="train")
    assert float(jnp.max(jnp.abs(lt - le))) < 0.05


def test_groups_cover_all_layers():
    for arch in ARCHS:
        cfg = get_config(arch)
        total = sum(len(g.period) * g.repeat for g in cfg.groups())
        assert total == cfg.n_layers, arch
