"""Serving correctness: prefill + decode == full forward (f32, exact math).

Covers every cache type: full-attention KV, sliding-window ring, SSM state +
conv tails, hybrid stacks, cross-attention, and MoE (no-drop capacity)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.configs import get_smoke_config
from repro.core.division_modes import DivisionConfig
from repro.models import forward, init_params
from repro.serving import ServingEngine, pad_cache_to

ARCHS = ["llama3_8b", "gemma3_12b", "mamba2_780m", "jamba_1_5_large",
         "whisper_tiny", "deepseek_moe_16b", "llava_next_mistral_7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), param_dtype="float32",
                              capacity_factor=8.0)
    if cfg.is_encoder_decoder:
        cfg = dataclasses.replace(cfg, encoder_seq=24)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S, EXTRA = 2, 32, 8
    total = S + 16  # window/chunk aligned
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)

    if cfg.embed_inputs and not cfg.is_encoder_decoder:
        # VLM: prefill on embeddings (stub frontend), decode on tokens
        embeds_full = jax.random.normal(key, (B, total, cfg.d_model),
                                        jnp.float32)
        emb_tab = params["embed"].astype(jnp.float32)
        embeds_full = embeds_full.at[:, S:].set(
            jnp.take(emb_tab, toks[:, S:], axis=0))
        full_logits, _, _ = forward(cfg, params, embeds=embeds_full,
                                    mode="train", **kw)
        _, cache, _ = forward(cfg, params, embeds=embeds_full[:, :S],
                              mode="prefill", **kw)
    else:
        full_logits, _, _ = forward(cfg, params, tokens=toks, mode="train", **kw)
        _, cache, _ = forward(cfg, params, tokens=toks[:, :S],
                              mode="prefill", **kw)

    cache = pad_cache_to(cache, S, total)
    errs = []
    for t in range(EXTRA):
        dl, cache, _ = forward(cfg, params, tokens=toks[:, S + t:S + t + 1],
                               cache=cache, pos=S + t, mode="decode", **kw)
        errs.append(float(jnp.max(jnp.abs(dl[:, 0] - full_logits[:, S + t]))))
    scale = float(jnp.max(jnp.abs(full_logits)))
    assert max(errs) / scale < 3e-4, f"{arch}: rel err {max(errs)/scale}"


# ------------------------------------------------ mode-parameterized gates

@pytest.mark.parametrize("mode", ["taylor", "goldschmidt", "taylor_pallas"])
def test_prefill_decode_matches_full_under_mode(mode):
    """The prefill+decode==full gate holds under every division mode the
    serving knob exposes, not just the config default. gemma3 exercises both
    decode cache paths (swa ring + global KV) through the mode's softmax and
    rmsnorm."""
    cfg = dataclasses.replace(
        get_smoke_config("gemma3_12b"), param_dtype="float32",
        division=DivisionConfig(mode=mode, n_iters=2))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S, EXTRA = 2, 32, 4
    total = S + 16
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    full_logits, _, _ = forward(cfg, params, tokens=toks, mode="train")
    _, cache, _ = forward(cfg, params, tokens=toks[:, :S], mode="prefill")
    cache = pad_cache_to(cache, S, total, cfg)
    errs = []
    for t in range(EXTRA):
        dl, cache, _ = forward(cfg, params, tokens=toks[:, S + t:S + t + 1],
                               cache=cache, pos=S + t, mode="decode")
        errs.append(float(jnp.max(jnp.abs(dl[:, 0] - full_logits[:, S + t]))))
    scale = float(jnp.max(jnp.abs(full_logits)))
    assert max(errs) / scale < 3e-4, f"{mode}: rel err {max(errs)/scale}"


# --------------------------------------- serving mode-equivalence (vs EXACT)

def _replay(engine, prompts, steps, teacher=None):
    """Greedy decode through the engine's own jit'd steps. With ``teacher``
    (the EXACT run's chosen tokens), feed that stream instead of the
    engine's own argmax so the two runs see identical context at every step
    (no divergence feedback). Returns (argmaxes (steps, B), logits
    (steps, B, V))."""
    lens = [len(p) for p in prompts]
    B = len(prompts)
    pad_to = engine._pad_to(max(lens))
    toks = np.zeros((B, pad_to), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p
    lengths = jnp.asarray(lens, jnp.int32)
    logits, cache = engine._prefill_tok(jnp.asarray(toks), lengths)
    cache = pad_cache_to(cache, pad_to, engine.max_len, engine.cfg)
    pos = lengths
    argmaxes, logit_seq = [], []
    for t in range(steps):
        logit_seq.append(np.asarray(logits))
        choice = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        argmaxes.append(np.asarray(choice[:, 0]))
        feed = choice if teacher is None else jnp.asarray(
            teacher[t])[:, None].astype(jnp.int32)
        logits, cache = engine._decode(cache, feed, pos)
        pos = pos + 1
    return np.stack(argmaxes), np.stack(logit_seq)


NON_ILM = ["taylor", "taylor_pallas", "goldschmidt", "goldschmidt_pallas"]


@pytest.mark.parametrize("arch,modes", [
    ("paper_fpdiv", NON_ILM),          # the paper's own config: every mode
    ("gemma3_12b", ["taylor"]),        # attention smoke (swa ring + global)
    ("jamba_1_5_large", ["goldschmidt"]),  # hybrid smoke (SSM + MoE + attn)
])
def test_serving_mode_equivalence_vs_exact(arch, modes):
    """Every non-ILM division mode, run as the serving knob, tracks the
    cfg=EXACT twin: >= 99% greedy-token agreement under teacher forcing and
    bounded logit drift."""
    cfg = dataclasses.replace(get_smoke_config(arch), param_dtype="float32",
                              capacity_factor=8.0)
    params = init_params(cfg, jax.random.PRNGKey(0))
    steps = 50 if arch == "paper_fpdiv" else 24
    prompts = [list(range(1, 14)), list(range(3, 20))]
    exact_eng = ServingEngine(cfg, params, max_len=96,
                              division=DivisionConfig(mode="exact"))
    teacher, exact_logits = _replay(exact_eng, prompts, steps)
    scale = float(np.max(np.abs(exact_logits)))
    for mode in modes:
        eng = ServingEngine(cfg, params, max_len=96,
                            division=DivisionConfig(mode=mode, n_iters=2))
        am, lg = _replay(eng, prompts, steps, teacher=teacher)
        agreement = float(np.mean(am == teacher))
        drift = float(np.max(np.abs(lg - exact_logits))) / scale
        assert agreement >= 0.99, f"{arch}/{mode}: agreement {agreement}"
        assert drift < 5e-3, f"{arch}/{mode}: logit drift {drift}"


def test_swa_ring_cache_wraps():
    """Decode past the window: ring slots recycle, result stays finite and
    matches a fresh prefill at every step."""
    cfg = dataclasses.replace(get_smoke_config("gemma3_12b"),
                              param_dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, W = 1, cfg.sliding_window  # 16
    total = 4 * W
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    full_logits, _, _ = forward(cfg, params, tokens=toks, mode="train")
    S = 2 * W
    _, cache, _ = forward(cfg, params, tokens=toks[:, :S], mode="prefill")
    cache = pad_cache_to(cache, S, total)
    for t in range(S, total):  # decode through 2 more windows
        dl, cache, _ = forward(cfg, params, tokens=toks[:, t:t + 1],
                               cache=cache, pos=t, mode="decode")
    err = float(jnp.max(jnp.abs(dl[:, 0] - full_logits[:, -1])))
    assert err / float(jnp.max(jnp.abs(full_logits))) < 3e-4
