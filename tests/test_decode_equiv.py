"""Serving correctness: prefill + decode == full forward (f32, exact math).

Covers every cache type: full-attention KV, sliding-window ring, SSM state +
conv tails, hybrid stacks, cross-attention, and MoE (no-drop capacity)."""
import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp
import jax.tree_util as jtu

from repro.configs import get_smoke_config
from repro.models import forward, init_params
from repro.serving import pad_cache_to

ARCHS = ["llama3_8b", "gemma3_12b", "mamba2_780m", "jamba_1_5_large",
         "whisper_tiny", "deepseek_moe_16b", "llava_next_mistral_7b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_full(arch):
    cfg = dataclasses.replace(get_smoke_config(arch), param_dtype="float32",
                              capacity_factor=8.0)
    if cfg.is_encoder_decoder:
        cfg = dataclasses.replace(cfg, encoder_seq=24)
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S, EXTRA = 2, 32, 8
    total = S + 16  # window/chunk aligned
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    kw = {}
    if cfg.is_encoder_decoder:
        kw["enc_embeds"] = jax.random.normal(
            key, (B, cfg.encoder_seq, cfg.d_model), jnp.float32)

    if cfg.embed_inputs and not cfg.is_encoder_decoder:
        # VLM: prefill on embeddings (stub frontend), decode on tokens
        embeds_full = jax.random.normal(key, (B, total, cfg.d_model),
                                        jnp.float32)
        emb_tab = params["embed"].astype(jnp.float32)
        embeds_full = embeds_full.at[:, S:].set(
            jnp.take(emb_tab, toks[:, S:], axis=0))
        full_logits, _, _ = forward(cfg, params, embeds=embeds_full,
                                    mode="train", **kw)
        _, cache, _ = forward(cfg, params, embeds=embeds_full[:, :S],
                              mode="prefill", **kw)
    else:
        full_logits, _, _ = forward(cfg, params, tokens=toks, mode="train", **kw)
        _, cache, _ = forward(cfg, params, tokens=toks[:, :S],
                              mode="prefill", **kw)

    cache = pad_cache_to(cache, S, total)
    errs = []
    for t in range(EXTRA):
        dl, cache, _ = forward(cfg, params, tokens=toks[:, S + t:S + t + 1],
                               cache=cache, pos=S + t, mode="decode", **kw)
        errs.append(float(jnp.max(jnp.abs(dl[:, 0] - full_logits[:, S + t]))))
    scale = float(jnp.max(jnp.abs(full_logits)))
    assert max(errs) / scale < 3e-4, f"{arch}: rel err {max(errs)/scale}"


def test_swa_ring_cache_wraps():
    """Decode past the window: ring slots recycle, result stays finite and
    matches a fresh prefill at every step."""
    cfg = dataclasses.replace(get_smoke_config("gemma3_12b"),
                              param_dtype="float32")
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, W = 1, cfg.sliding_window  # 16
    total = 4 * W
    toks = jax.random.randint(key, (B, total), 0, cfg.vocab)
    full_logits, _, _ = forward(cfg, params, tokens=toks, mode="train")
    S = 2 * W
    _, cache, _ = forward(cfg, params, tokens=toks[:, :S], mode="prefill")
    cache = pad_cache_to(cache, S, total)
    for t in range(S, total):  # decode through 2 more windows
        dl, cache, _ = forward(cfg, params, tokens=toks[:, t:t + 1],
                               cache=cache, pos=t, mode="decode")
    err = float(jnp.max(jnp.abs(dl[:, 0] - full_logits[:, -1])))
    assert err / float(jnp.max(jnp.abs(full_logits))) < 3e-4
