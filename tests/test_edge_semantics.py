"""IEEE/hardware edge contract for recip/div/rsqrt in EVERY division mode.

The contract every mode (exact XLA, Taylor jnp, Taylor Pallas, Goldschmidt,
Goldschmidt Pallas, ILM emulation) must honor:

    +-0 -> +-inf      +-inf -> +-0      nan -> nan      sign preserved

rsqrt follows jax.lax.rsqrt: +-0 -> +-inf, +inf -> +0, x < 0 (incl -inf)
and nan -> nan.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import division_modes as dm

ALL_MODES = list(dm.MODES)


def _cfg(mode):
    return dm.DivisionConfig(mode=mode)


@pytest.mark.parametrize("mode", ALL_MODES)
def test_recip_edges_and_signs(mode):
    x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, 2.0, -2.0,
                     0.25, -0.25], jnp.float32)
    r = np.asarray(dm.recip(x, _cfg(mode)))
    assert np.isposinf(r[0]), (mode, r[0])
    assert np.isneginf(r[1]), (mode, r[1])
    assert r[2] == 0.0 and not np.signbit(r[2]), (mode, r[2])
    assert r[3] == 0.0 and np.signbit(r[3]), (mode, r[3])
    assert np.isnan(r[4]), (mode, r[4])
    # Sign preservation on finite operands.
    assert r[5] > 0 and r[6] < 0 and r[7] > 0 and r[8] < 0, (mode, r[5:])


@pytest.mark.parametrize("mode", ALL_MODES)
def test_div_edges_and_signs(mode):
    cfg = _cfg(mode)
    a = jnp.asarray([1.0, -1.0, 1.0, -1.0, 0.0, np.inf, 1.0, 1.0,
                     np.nan, 1.0, 6.0, -6.0], jnp.float32)
    b = jnp.asarray([0.0, 0.0, -0.0, -0.0, 0.0, np.inf, np.inf, -np.inf,
                     1.0, np.nan, 3.0, 3.0], jnp.float32)
    q = np.asarray(dm.div(a, b, cfg))
    assert np.isposinf(q[0]), (mode, q[0])      # 1 / +0
    assert np.isneginf(q[1]), (mode, q[1])      # -1 / +0
    assert np.isneginf(q[2]), (mode, q[2])      # 1 / -0
    assert np.isposinf(q[3]), (mode, q[3])      # -1 / -0
    assert np.isnan(q[4]), (mode, q[4])         # 0 / 0
    assert np.isnan(q[5]), (mode, q[5])         # inf / inf
    assert q[6] == 0.0 and not np.signbit(q[6]), (mode, q[6])   # 1 / +inf
    assert q[7] == 0.0 and np.signbit(q[7]), (mode, q[7])       # 1 / -inf
    assert np.isnan(q[8]) and np.isnan(q[9]), (mode, q[8:10])   # nan prop
    tol = 0.05 if mode == "ilm" else 1e-5
    assert abs(q[10] - 2.0) < tol and abs(q[11] + 2.0) < tol, (mode, q[10:])


@pytest.mark.parametrize("mode", ALL_MODES)
def test_rsqrt_edges(mode):
    x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, 4.0, -4.0],
                    jnp.float32)
    r = np.asarray(dm.rsqrt(x, _cfg(mode)))
    assert np.isposinf(r[0]), (mode, r[0])
    assert np.isneginf(r[1]), (mode, r[1])
    assert r[2] == 0.0 and not np.signbit(r[2]), (mode, r[2])
    assert np.isnan(r[3]), (mode, r[3])         # rsqrt(-inf)
    assert np.isnan(r[4]), (mode, r[4])
    assert abs(r[5] - 0.5) < 1e-5, (mode, r[5])
    assert np.isnan(r[6]), (mode, r[6])         # rsqrt of negative


@pytest.mark.parametrize("mode", ALL_MODES)
def test_recip_edges_bf16(mode):
    """The contract survives the bf16 in/out cast."""
    x = jnp.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, -2.0], jnp.bfloat16)
    r = np.asarray(dm.recip(x, _cfg(mode)), np.float32)
    assert np.isposinf(r[0]) and np.isneginf(r[1]), (mode, r[:2])
    assert r[2] == 0.0 and r[3] == 0.0 and np.signbit(r[3]), (mode, r[2:4])
    assert np.isnan(r[4]) and r[5] < 0, (mode, r[4:])
