import os

# Tests run on the single real CPU device (the 512-device forcing happens ONLY
# inside launch/dryrun.py, which tests exercise via subprocess).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
