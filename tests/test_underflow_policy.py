"""The underflow-policy switch: ftz vs gradual, and nothing else.

DivisionConfig(underflow=...) selects the jnp twins' subnormal handling:
"gradual" (default) is exact IEEE gradual underflow through the bit-level
datapath, "ftz" is the fused kernels' hardware flush contract. The gates:

  (a) the two policies differ *exactly* on the subnormal classes —
      subnormal operands, results that round into (or flush out of) the
      subnormal range — and nowhere else;
  (b) bit-identity on the normal-range lanes of the committed golden
      stores holds for BOTH policies (the datapath refactor is
      numerics-preserving outside the subnormal classes);
  (c) the underflow="ftz" jnp twins are bit-identical to the fused Pallas
      kernels on the full corpus — subnormal, edge and normal lanes alike
      (the field-for-field alignment the tentpole promises);
  (d) under gradual, the jnp twins return finite <= 2 ULP quotients on the
      subnormal-operand corpus that PR 2 had to mask, and gradual-underflow
      *results* are correctly rounded into the subnormal lattice.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.core import goldschmidt, taylor
from repro.core.seeds import compute_segments
from repro.eval import golden, ulp

TINY = np.ldexp(1.0, -126)
JNP_MODES = ["taylor", "goldschmidt"]


def _subnormal(x64):
    return np.isfinite(x64) & (x64 != 0) & (np.abs(x64) < TINY)


def _policy_pair(mode, a, b):
    qg = np.asarray(dm.div(jnp.asarray(a), jnp.asarray(b),
                           dm.DivisionConfig(mode=mode, underflow="gradual")))
    qf = np.asarray(dm.div(jnp.asarray(a), jnp.asarray(b),
                           dm.DivisionConfig(mode=mode, underflow="ftz")))
    return qg, qf


@pytest.mark.parametrize("mode", JNP_MODES)
def test_policies_differ_only_on_subnormal_classes(mode):
    """ftz vs gradual: every differing lane is a subnormal class — a
    subnormal operand, a subnormal gradual result, or a result the flush
    removed (gradual kept a value <= smallest normal where ftz gives 0)."""
    a, b = golden.golden_div_inputs()
    qg, qf = _policy_pair(mode, a, b)
    differ = ulp.ulp_diff(qg, qf) > 0
    a64, b64 = a.astype(np.float64), b.astype(np.float64)
    qg64 = qg.astype(np.float64)
    flushed = (qf == 0) & (qg != 0) & (np.abs(qg64) <= TINY)
    sub_class = _subnormal(a64) | _subnormal(b64) | _subnormal(qg64) | flushed
    outside = differ & ~sub_class
    assert not outside.any(), [
        (float(a[i]), float(b[i]), float(qg[i]), float(qf[i]))
        for i in np.where(outside)[0][:5]]
    # The switch is not a no-op: the corpus has lanes where they differ.
    assert differ.any(), "no subnormal-class lanes exercised"


@pytest.mark.parametrize("mode", JNP_MODES)
def test_both_policies_bit_identical_on_normal_golden_lanes(mode):
    """Normal-range golden bit-identity holds for BOTH policies."""
    with np.load(golden.DIVIDE_PATH) as z:
        a, b = z["a"], z["b"]
        key = f"div/{mode}/n2p24" if mode == "goldschmidt" else \
            "div/taylor/factored/n2p24"
        want = z["out:" + key].view(np.float32)
    qg, qf = _policy_pair(mode, a, b)
    a64, b64 = a.astype(np.float64), b.astype(np.float64)
    qg64 = qg.astype(np.float64)
    flushed = (qf == 0) & (qg != 0) & (np.abs(qg64) <= TINY)
    normal = ~(_subnormal(a64) | _subnormal(b64) | _subnormal(qg64) | flushed)
    assert normal.sum() > 1000                      # the corpus is mostly normal
    assert ulp.ulp_diff(qg, want)[normal].max() == 0, mode
    assert ulp.ulp_diff(qf, want)[normal].max() == 0, mode


@pytest.mark.parametrize("mode,twin", [
    ("taylor_pallas",
     lambda a, b: taylor.divide(a, b, compute_segments(2, 24),
                                schedule="factored", underflow="ftz")),
    ("goldschmidt_pallas",
     lambda a, b: goldschmidt.divide(a, b, compute_segments(2, 24),
                                     iters=goldschmidt.iters_for_terms(2),
                                     underflow="ftz")),
])
def test_ftz_twin_bit_identical_to_fused_divide_kernel(mode, twin):
    """The field-for-field alignment gate: jit'd underflow="ftz" twin ==
    fused kernel, bit for bit, on normal + subnormal + IEEE edge lanes."""
    a, b = golden.golden_div_inputs()
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    qk = np.asarray(dm.div(aj, bj, dm.DivisionConfig(mode=mode)))
    qt = np.asarray(jax.jit(twin)(aj, bj))
    d = ulp.ulp_diff(qk, qt)
    assert d.max() == 0, (mode, int(d.max()),
                          [(float(a[i]), float(b[i]))
                           for i in np.where(d > 0)[0][:5]])


@pytest.mark.parametrize("mode,twin", [
    ("taylor_pallas",
     lambda x: taylor.reciprocal(x, compute_segments(2, 24),
                                 schedule="factored", underflow="ftz")),
    ("goldschmidt_pallas",
     lambda x: goldschmidt.reciprocal(x, compute_segments(2, 24),
                                      iters=goldschmidt.iters_for_terms(2),
                                      underflow="ftz")),
])
def test_ftz_twin_bit_identical_to_fused_recip_kernel(mode, twin):
    x = golden.golden_inputs()
    xj = jnp.asarray(x)
    rk = np.asarray(dm.recip(xj, dm.DivisionConfig(mode=mode)))
    rt = np.asarray(jax.jit(twin)(xj))
    d = ulp.ulp_diff(rk, rt)
    assert d.max() == 0, (mode, [float(x[i]) for i in np.where(d > 0)[0][:5]])


@pytest.mark.parametrize("mode", JNP_MODES)
def test_gradual_subnormal_operand_corpus_2ulp(mode):
    """Acceptance gate: the subnormal-operand div corpus measures finite
    and <= 2 ULP under gradual (PR 2 masked these lanes entirely)."""
    b = ulp.sweep_subnormals(512, "float32", seed=21)
    a = ulp.sweep_logspace(512, "float32", seed=22)
    # Add subnormal numerators and subnormal/subnormal pairs.
    a2 = ulp.sweep_subnormals(256, "float32", seed=23)
    b2 = ulp.sweep_logspace(256, "float32", seed=24)
    a3 = ulp.sweep_subnormals(128, "float32", seed=25)
    b3 = ulp.sweep_subnormals(128, "float32", seed=26)
    aa = np.concatenate([a, a2, a3]).astype(np.float32)
    bb = np.concatenate([b, b2, b3]).astype(np.float32)
    a64, b64 = aa.astype(np.float64), bb.astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        exact = a64 / b64
    mask = ((ulp.oracle_mask(exact) | ulp.subnormal_mask(exact))
            & ulp.overflow_guard(exact))
    assert mask.sum() > 300
    cfg = dm.DivisionConfig(mode=mode)          # gradual is the default
    q = np.asarray(dm.div(jnp.asarray(aa), jnp.asarray(bb), cfg))
    assert not np.isnan(q[mask]).any(), mode
    errs = ulp.ulp_error(q, exact, where=mask)
    assert errs.max() <= 2.0, (mode, errs.max())


@pytest.mark.parametrize("mode", JNP_MODES)
def test_gradual_underflow_results_correctly_rounded(mode):
    """Quotients of normal operands that land subnormal are RNE-exact
    against numpy's correctly rounded f64 -> f32 cast for exact ratios,
    and <= 2 ULP in general."""
    cfg = dm.DivisionConfig(mode=mode)
    # Exactly representable ratios: bit-exact after the integer repack.
    a = np.asarray([1.5 * 2.0 ** -120, 2.0 ** -100, 1.25 * 2.0 ** -119,
                    -(1.5 * 2.0 ** -120)], np.float32)
    b = np.asarray([2.0 ** 9, 2.0 ** 48, 2.0 ** 20, 2.0 ** 9], np.float32)
    q = np.asarray(dm.div(jnp.asarray(a), jnp.asarray(b), cfg))
    want = (a.astype(np.float64) / b.astype(np.float64)).astype(np.float32)
    np.testing.assert_array_equal(q.view(np.uint32), want.view(np.uint32))
    assert _subnormal(want.astype(np.float64)).all()    # really subnormal
    # General straddling corpus: <= 2 ULP in subnormal-lattice ULPs.
    aq, bq = ulp.sweep_quotient_edges(1024, "float32", seed=31)
    a64, b64 = aq.astype(np.float64), bq.astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        exact = a64 / b64
    mask = ulp.subnormal_mask(exact)
    assert mask.sum() > 50
    q = np.asarray(dm.div(jnp.asarray(aq), jnp.asarray(bq), cfg))
    errs = ulp.ulp_error(q, exact, where=mask)
    assert errs.max() <= 2.0, (mode, errs.max())


def test_gradual_recip_subnormal_results():
    """recip of near-maxfloat inputs rounds into the subnormal range."""
    x = np.asarray([3.2e38, -3.2e38, 2.0 ** 127], np.float32)
    r = np.asarray(dm.recip(jnp.asarray(x), dm.TAYLOR))
    exact = 1.0 / x.astype(np.float64)
    assert _subnormal(exact).all()
    errs = ulp.ulp_error(r, exact, where=np.isfinite(exact))
    assert errs.max() <= 1.0, errs
    # and ftz flushes the same lanes to signed zero
    rf = np.asarray(dm.recip(jnp.asarray(x),
                             dm.DivisionConfig(mode="taylor", underflow="ftz")))
    assert np.all(rf == 0) and list(np.signbit(rf)) == [False, True, False]


def test_underflow_config_validation():
    with pytest.raises(ValueError, match="underflow"):
        dm.DivisionConfig(mode="taylor", underflow="bogus")


def test_effective_underflow_reporting():
    assert dm.effective_underflow(dm.TAYLOR) == "gradual"
    assert dm.effective_underflow(
        dm.DivisionConfig(mode="taylor", underflow="ftz")) == "ftz"
    for mode in ("taylor_pallas", "goldschmidt_pallas", "ilm", "exact"):
        assert dm.effective_underflow(dm.DivisionConfig(mode=mode)) == "ftz"
