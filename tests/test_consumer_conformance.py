"""Consumer-tier conformance: mode-faithful softmax/rmsnorm/attention/rsqrt.

What PR 4's grid could not see: ``division_modes.rsqrt`` silently ran the
jnp Taylor datapath for the Pallas and ILM modes, and ``softmax`` never
routed to the fused kernel at all — a user who configured the fused unit got
a different implementation with no error. This module gates the fix:

  (a) dispatch spies: every consumer op routes each mode to the
      implementation the config names (fused kernels for the Pallas modes,
      with schedule="goldschmidt" threaded; real ILM arithmetic for ilm) and
      the jnp modes never touch a kernel;
  (b) masked softmax: fully-masked rows return zeros in every mode (never
      0 * recip(0) = nan), single-survivor rows are one-hot, bf16 included;
  (c) the consumer gates: row sums within 2 ULP-equivalents of 1.0 and
      outputs within the documented vs-exact-twin tolerance (non-ILM);
  (d) the conformance grid carries the consumer cells and the committed
      golden/softmax_v1.npz store checks bit-exact;
  (e) rsqrt gradients ride a custom_jvp rule (subnormal primals stay exact,
      gradient lanes degrade to zero rather than nan).
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.eval import conformance, consumers, golden, ulp

NON_ILM = [
    ("exact", "-"),
    ("taylor", "paper"),
    ("taylor", "factored"),
    ("taylor_pallas", "factored"),
    ("goldschmidt", "-"),
    ("goldschmidt_pallas", "-"),
]


def _cfg(mode, sched="-"):
    return dm.DivisionConfig(
        mode=mode, schedule=sched if sched != "-" else "factored")


# --------------------------------------------------------------- dispatch

def test_softmax_pallas_modes_use_fused_kernel(monkeypatch):
    """Both Pallas modes must lower softmax to the fused kernel, with the
    schedule the mode names — never the jnp twin silently."""
    from repro.kernels import ops as kops

    schedules = []
    real = kops.softmax

    def spy(x, *, n_iters=2, precision_bits=24, schedule="factored"):
        schedules.append(schedule)
        return real(x, n_iters=n_iters, precision_bits=precision_bits,
                    schedule=schedule)

    monkeypatch.setattr(kops, "softmax", spy)
    x = jnp.asarray(np.linspace(-3, 3, 8 * 128).reshape(8, 128), jnp.float32)
    s = dm.softmax(x, -1, dm.DivisionConfig(mode="taylor_pallas"))
    np.testing.assert_allclose(np.asarray(s).sum(-1), 1.0, rtol=1e-6)
    assert schedules == ["factored"]
    schedules.clear()
    dm.softmax(x, -1, dm.DivisionConfig(mode="goldschmidt_pallas"))
    assert schedules == ["goldschmidt"]


def test_rsqrt_pallas_modes_use_fused_kernel(monkeypatch):
    """The PR 4 silent fallthrough, pinned dead: both Pallas modes lower
    rsqrt to the fused kernel, never the jnp Taylor twin."""
    from repro.core import taylor
    from repro.kernels import ops as kops

    calls = []
    real = kops.tsdiv_rsqrt

    def spy(x, newton_iters=2, n_segments=16):
        calls.append(newton_iters)
        return real(x, newton_iters, n_segments)

    def forbidden(*a, **kw):
        raise AssertionError("Pallas rsqrt fell back to the jnp twin")

    monkeypatch.setattr(kops, "tsdiv_rsqrt", spy)
    monkeypatch.setattr(taylor, "rsqrt", forbidden)
    x = jnp.asarray([0.25, 4.0, 9.0], jnp.float32)
    for mode in ("taylor_pallas", "goldschmidt_pallas"):
        r = dm.rsqrt(x, dm.DivisionConfig(mode=mode, rsqrt_newton=3))
        np.testing.assert_allclose(np.asarray(r), [2.0, 0.5, 1 / 3.0],
                                   rtol=1e-6)
    assert calls == [3, 3]


def test_rmsnorm_and_attention_pallas_dispatch(monkeypatch):
    from repro.kernels import ops as kops

    rms_calls, fa_scheds = [], []
    real_rms, real_fa = kops.rmsnorm, kops.flash_attention

    def rms_spy(x, w, *, eps=1e-6, newton_iters=2, n_segments=16):
        rms_calls.append((newton_iters, n_segments))
        return real_rms(x, w, eps=eps, newton_iters=newton_iters,
                        n_segments=n_segments)

    def fa_spy(q, k, v, *, schedule="factored", **kw):
        fa_scheds.append(schedule)
        return real_fa(q, k, v, schedule=schedule, **kw)

    monkeypatch.setattr(kops, "rmsnorm", rms_spy)
    monkeypatch.setattr(kops, "flash_attention", fa_spy)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
    dm.rmsnorm(x, w, dm.DivisionConfig(mode="taylor_pallas"))
    assert rms_calls == [(2, 16)]
    q = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    dm.attention(q, q, q, dm.DivisionConfig(mode="taylor_pallas"))
    dm.attention(q, q, q, dm.DivisionConfig(mode="goldschmidt_pallas"))
    assert fa_scheds == ["factored", "goldschmidt"]


def test_jnp_modes_never_touch_kernels(monkeypatch):
    """exact/taylor/goldschmidt/ilm consumers must not launch a kernel."""
    from repro.kernels import ops as kops

    def forbidden(*a, **kw):
        raise AssertionError("jnp mode dispatched to a Pallas kernel")

    for name in ("softmax", "rmsnorm", "flash_attention", "tsdiv_rsqrt",
                 "tsdiv_recip", "tsdiv_divide"):
        monkeypatch.setattr(kops, name, forbidden)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
    q = jnp.asarray(rng.normal(size=(1, 16, 8)).astype(np.float32))
    for mode in ("exact", "taylor", "goldschmidt", "ilm"):
        cfg = dm.DivisionConfig(mode=mode)
        dm.softmax(x, -1, cfg)
        dm.rmsnorm(x, w, cfg)
        dm.rsqrt(jnp.abs(x) + 0.1, cfg)
        dm.attention(q, q, q, cfg)


def test_rsqrt_ilm_is_genuinely_ilm():
    """mode="ilm" rsqrt runs the 12-bit ILM Newton arithmetic — measurably
    approximate, not the silently-substituted 24-bit Taylor twin."""
    x = jnp.asarray(np.linspace(1.0, 4.0, 512), jnp.float32)
    r = np.asarray(dm.rsqrt(x, dm.DivisionConfig(mode="ilm")))
    rel = np.abs(r * np.sqrt(np.asarray(x)) - 1)
    assert rel.max() < 5e-3          # 12-bit regime
    assert rel.max() > 1e-6          # genuinely not the f32 datapath


def test_softmax_axis_handling_pallas():
    """Non-last axes move through the kernel path and back."""
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32))
    cfg = dm.DivisionConfig(mode="taylor_pallas")
    s0 = np.asarray(dm.softmax(x, 0, cfg))
    np.testing.assert_allclose(s0.sum(0), 1.0, rtol=1e-5)
    e0 = np.asarray(jax.nn.softmax(x, 0))
    np.testing.assert_allclose(s0, e0, atol=1e-6)


# ---------------------------------------------------------- masked softmax

@pytest.mark.parametrize("mode,sched", NON_ILM + [("ilm", "-")])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_softmax_masked_matrix(mode, sched, dtype):
    """all-False row -> zeros; single-survivor row -> one-hot; surviving
    rows renormalize — in every mode, both dtypes."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.normal(size=(3, 16)), dtype)
    where = jnp.asarray(np.stack([np.zeros(16, bool),
                                  np.eye(16, dtype=bool)[5],
                                  np.arange(16) < 9]))
    s = np.asarray(dm.softmax(x, -1, _cfg(mode, sched), where=where),
                   np.float32)
    assert np.all(s[0] == 0.0), (mode, s[0])
    tol = 2e-3 if mode == "ilm" else 2e-6
    assert abs(s[1, 5] - 1.0) <= tol, (mode, s[1, 5])
    assert np.all(s[1, np.arange(16) != 5] == 0.0)
    assert np.all(s[2, 9:] == 0.0)
    assert abs(s[2].sum() - 1.0) <= (1e-2 if dtype == jnp.bfloat16 else tol)
    assert np.all(np.isfinite(s))


@pytest.mark.parametrize("mode,sched", NON_ILM)
def test_softmax_all_neg_inf_row_returns_zeros(mode, sched):
    """The unmasked spelling of a fully-masked row (all logits -inf)."""
    x = jnp.asarray(np.array([[-np.inf] * 8, [0.0] + [-np.inf] * 7]),
                    jnp.float32)
    s = np.asarray(dm.softmax(x, -1, _cfg(mode, sched)))
    assert np.all(s[0] == 0.0), (mode, s[0])
    assert s[1, 0] == pytest.approx(1.0, abs=2e-6) and np.all(s[1, 1:] == 0.0)


def test_softmax_masked_grad_no_nan():
    """Gradients through a batch containing a fully-masked row stay finite."""
    x = jnp.asarray(np.random.default_rng(4).normal(size=(2, 8)), jnp.float32)
    where = jnp.asarray(np.stack([np.zeros(8, bool), np.ones(8, bool)]))
    for mode, sched in NON_ILM:
        g = jax.grad(lambda v: dm.softmax(v, -1, _cfg(mode, sched),
                                          where=where)[1].sum())(x)
        assert bool(jnp.all(jnp.isfinite(g))), mode


# ------------------------------------------------------------ accuracy gates

@pytest.fixture(scope="module")
def softmax_corpus():
    strata = consumers.softmax_rows("float32", n_rows=32, d=128, seed=5)
    return {k: jnp.asarray(v) for k, v in strata.items()}


@pytest.mark.parametrize("mode,sched", NON_ILM)
def test_softmax_row_sums_within_2_ulp(softmax_corpus, mode, sched):
    """The acceptance gate: at the conformance shape (D=128) every non-ILM
    mode's rows sum to 1 within 2 ULP-equivalents. (Larger D adds the f32
    accumulation error of the sum itself — shared with the exact twin.)"""
    cfg = _cfg(mode, sched)
    for name, xj in softmax_corpus.items():
        out = np.asarray(dm.softmax(xj, -1, cfg))
        rs = consumers.row_sum_ulp1(out).max()
        assert rs <= consumers.ROW_SUM_GATE_ULP, (mode, name, rs)


@pytest.mark.parametrize("mode,sched", [m for m in NON_ILM
                                        if m[0] != "exact"])
def test_softmax_vs_exact_twin_tolerance(softmax_corpus, mode, sched):
    cfg = _cfg(mode, sched)
    for name, xj in softmax_corpus.items():
        out = np.asarray(dm.softmax(xj, -1, cfg))
        twin = np.asarray(dm.softmax(xj, -1, dm.EXACT))
        oracle = consumers.softmax_oracle(np.asarray(xj, np.float64))
        ve = consumers.vs_exact_int_ulp(out, twin, oracle)
        assert ve <= consumers.VS_EXACT_GATE_ULP, (mode, name, ve)


@pytest.mark.parametrize("mode,sched", [m for m in NON_ILM
                                        if m[0] != "exact"])
def test_rmsnorm_vs_exact_twin_tolerance(mode, sched):
    cfg = _cfg(mode, sched)
    strata = consumers.rmsnorm_rows("float32", n_rows=32, d=128, seed=6)
    w = consumers.rmsnorm_weight(128, seed=6)
    wj = jnp.asarray(w)
    for name, xs in strata.items():
        out = np.asarray(dm.rmsnorm(jnp.asarray(xs), wj, cfg))
        twin = np.asarray(dm.rmsnorm(jnp.asarray(xs), wj, dm.EXACT))
        oracle = consumers.rmsnorm_oracle(xs.astype(np.float64),
                                          w.astype(np.float64))
        ve = consumers.vs_exact_int_ulp(out, twin, oracle)
        assert ve <= consumers.VS_EXACT_GATE_ULP, (mode, name, ve)


@pytest.mark.parametrize("mode,sched", NON_ILM)
def test_attention_close_to_exact_twin(mode, sched):
    rng = np.random.default_rng(7)
    q = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 64, 32)).astype(np.float32))
    for causal in (True, False):
        o = np.asarray(dm.attention(q, k, v, _cfg(mode, sched),
                                    causal=causal))
        e = np.asarray(dm.attention(q, k, v, dm.EXACT, causal=causal))
        assert np.max(np.abs(o - e)) <= 1e-5, (mode, causal)


def test_attention_ilm_runs_and_is_approximate():
    rng = np.random.default_rng(8)
    q = jnp.asarray(rng.normal(size=(1, 16, 8)).astype(np.float32))
    o = np.asarray(dm.attention(q, q, q, dm.DivisionConfig(mode="ilm")))
    e = np.asarray(dm.attention(q, q, q, dm.EXACT))
    dev = np.max(np.abs(o - e))
    assert np.all(np.isfinite(o)) and dev < 1e-2 and dev > 1e-8


def test_attention_ragged_seq_through_pallas_mode():
    """Seq lens like 100 stream through the fused kernel via pad-and-mask."""
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(2, 100, 32)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(2, 100, 32)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(2, 100, 32)).astype(np.float32))
    o = np.asarray(dm.attention(q, k, v, dm.DivisionConfig(mode="taylor_pallas")))
    e = np.asarray(dm.attention(q, k, v, dm.EXACT))
    assert o.shape == (2, 100, 32)
    np.testing.assert_allclose(o, e, atol=5e-6)


# -------------------------------------------------- grid + golden wiring

def test_consumer_grid_cells_present():
    cells = conformance.default_grid()
    for op in consumers.CONSUMER_OPS:
        got = {(c.mode, c.schedule, c.dtype) for c in cells if c.op == op}
        for dt in ("float32", "bfloat16"):
            assert ("exact", "-", dt) in got, op
            assert ("taylor", "factored", dt) in got, op
            assert ("taylor_pallas", "factored", dt) in got, op
            assert ("goldschmidt_pallas", "-", dt) in got, op
            assert ("ilm", "-", dt) in got, op
    rs = {(c.mode, c.schedule) for c in cells if c.op == "rsqrt"}
    # Both Pallas modes share the fused rsqrt kernel (no schedule knob), so
    # one fused-kernel cell measures them both.
    assert ("taylor_pallas", "factored") in rs


@pytest.mark.parametrize("op", list(consumers.CONSUMER_OPS))
def test_consumer_cell_runner_gates(op):
    rep = conformance.run_cell(
        conformance.Cell("taylor", "factored", 2, 24, op=op),
        n_log=256, n_man=256)
    assert rep["edge_failures"] == 0
    assert rep["vs_exact_max_ulp"] <= consumers.VS_EXACT_GATE_ULP
    if op == "softmax":
        assert rep["row_sum_max_ulp1"] <= consumers.ROW_SUM_GATE_ULP
    assert rep["pass"] is True


def test_softmax_golden_vectors_unchanged():
    """Committed op=softmax golden store: drift fails loudly, by cell name."""
    assert golden.SOFTMAX_PATH.exists(), (
        "softmax golden store missing — run "
        "`python -m repro.eval.golden --generate --store softmax`")
    failures = golden.check_softmax()
    assert failures == [], failures


# ------------------------------------------------------- rsqrt gradients

def test_rsqrt_grad_matches_analytic():
    for mode, sched in NON_ILM:
        cfg = _cfg(mode, sched)
        x = jnp.asarray([0.25, 2.0, 1e4, 2.0 ** -40], jnp.float32)
        g = jax.grad(lambda v: dm.rsqrt(v, cfg).sum())(x)
        want = -0.5 * np.asarray(x, np.float64) ** -1.5
        np.testing.assert_allclose(np.asarray(g), want, rtol=1e-5,
                                   err_msg=mode)


def test_rsqrt_subnormal_primal_exact_with_finite_grad():
    """The custom_jvp port (ROADMAP open item): a subnormal primal stays
    bit-exact under the gradual policy while the gradient lane (whose
    analytic -r^3/2 overflows f32) degrades to zero — never nan, and never
    a flushed primal."""
    x = jnp.asarray([2.0 ** -130, 2.0 ** -140, 2.0 ** -149], jnp.float32)
    cfg = dm.DivisionConfig(mode="taylor")
    r, vjp = jax.vjp(lambda v: dm.rsqrt(v, cfg), x)
    exact = 1.0 / np.sqrt(np.asarray(x, np.float64))
    errs = ulp.ulp_error(np.asarray(r), exact)
    assert errs.max() <= 1.0                       # primal exact-as-gated
    (g,) = vjp(jnp.ones_like(r))
    assert bool(jnp.all(jnp.isfinite(g)))          # masked, not nan/inf
    # forward mode must work too (custom_jvp, not custom_vjp)
    _, t = jax.jvp(lambda v: dm.rsqrt(v, cfg), (x,), (jnp.ones_like(x),))
    assert bool(jnp.all(jnp.isfinite(t)))


def test_rsqrt_grad_through_fused_kernel_edges():
    """Kernel rsqrt gradients at IEEE edges are masked to zero, not nan."""
    from repro.kernels import ops as kops

    x = jnp.asarray([4.0, 0.0, np.inf, 2.0 ** -130], jnp.float32)
    g = jax.grad(lambda v: jnp.sum(jnp.where(jnp.isfinite(
        kops.tsdiv_rsqrt(v)), kops.tsdiv_rsqrt(v), 0.0)))(x)
    assert bool(jnp.all(jnp.isfinite(g)))
    assert abs(float(g[0]) + 0.5 * 4.0 ** -1.5) < 1e-6


# ------------------------------------------------- fused rsqrt kernel twin

def test_fused_rsqrt_bit_identical_to_ftz_twin():
    """The fused kernel and the underflow="ftz" jnp twin are one datapath,
    field for field — subnormal operands and IEEE edges included."""
    from repro.core import taylor
    from repro.core.seeds import rsqrt_seed_table
    from repro.kernels import ops as kops

    x = np.concatenate([
        np.abs(ulp.sweep_logspace(2048, "float32", 20)),
        ulp.sweep_rsqrt_mantissa(1024, "float32", 21),
        ulp.sweep_edges("float32"),
        ulp.sweep_subnormals(256, "float32", 22),
    ]).astype(np.float32)
    k = np.asarray(kops.tsdiv_rsqrt(jnp.asarray(x)))
    t = np.asarray(taylor.rsqrt(jnp.asarray(x), rsqrt_seed_table(16),
                                newton_iters=2, underflow="ftz"))
    d = ulp.ulp_diff(k, t)
    assert int(d.max()) == 0, (int(d.max()), int((d > 0).sum()))


def test_fused_rsqrt_bf16_passthrough():
    from repro.kernels import ops as kops

    x = jnp.asarray(np.linspace(0.5, 8.0, 64), jnp.bfloat16)
    r = kops.tsdiv_rsqrt(x)
    assert r.dtype == jnp.bfloat16
    rel = np.abs(np.asarray(r, np.float32)
                 * np.sqrt(np.asarray(x, np.float32)) - 1)
    assert rel.max() < 0.01
