"""op=rsqrt conformance gates: the divide-free Givens datapath, first class.

rsqrt is the operation the hardware Givens-rotation unit is built around
(Hormigo & Muñoz, arXiv:2010.12376) and the ``via="rsqrt"`` formulation of
our QR workload. This module promotes it to the same footing as recip/div:

  (a) a <= 2 max ULP hard gate vs the f64 oracle over the stratified rsqrt
      sweep (odd/even exponent split, two-octave mantissa corpus) for
      taylor (paper + factored, n=2 @ 24-bit) and goldschmidt configs —
      the compensated final Newton step actually delivers ~0.5 ULP;
  (b) subnormal operands exact under the gradual policy (the corpus PR 2
      had to mask), the zero class under ftz;
  (c) the op=rsqrt column present in the conformance grid;
  (d) a committed golden store (golden/rsqrt_v1.npz) wired into --check;
  (e) the IEEE edge contract in every mode.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.core import taylor
from repro.eval import conformance, golden, ulp

GATED_CFGS = [
    ("taylor/paper", dm.DivisionConfig(mode="taylor", schedule="paper",
                                       n_iters=2, precision_bits=24)),
    ("taylor/factored", dm.DivisionConfig(mode="taylor", schedule="factored",
                                          n_iters=2, precision_bits=24)),
    ("goldschmidt", dm.DivisionConfig(mode="goldschmidt", n_iters=2,
                                      precision_bits=24)),
]


@pytest.fixture(scope="module")
def rsqrt_sweep_f32():
    """Stratified positive sweep, masked to normal operands and results."""
    strata = ulp.rsqrt_sweep("float32", n_log=4096, n_man=4096)
    x = np.concatenate([np.asarray(s, np.float32) for s in strata.values()])
    x64 = x.astype(np.float64)
    with np.errstate(divide="ignore", invalid="ignore"):
        exact = 1.0 / np.sqrt(x64)
    keep = ulp.oracle_mask(exact) & ulp.oracle_mask(x64)
    return x[keep], exact[keep]


class TestHardGate:
    @pytest.mark.parametrize("name,cfg", GATED_CFGS)
    def test_rsqrt_within_2ulp(self, rsqrt_sweep_f32, name, cfg):
        """Eq. 17-style gate at the f32 operating point — and the
        compensated final Newton step is in fact near-correctly-rounded."""
        x, exact = rsqrt_sweep_f32
        r = np.asarray(dm.rsqrt(jnp.asarray(x), cfg))
        errs = ulp.ulp_error(r, exact)
        assert errs.max() <= 2.0, (name, errs.max())
        assert errs.max() <= 1.0, (name, errs.max())

    def test_rsqrt_subnormal_operands_exact_gradual(self):
        """The corpus PR 2 had to mask: subnormal operands now measure
        <= 2 ULP (in fact sub-ULP) and are always finite under gradual."""
        x = np.abs(ulp.sweep_subnormals(512, "float32", seed=9)).astype(np.float32)
        x = np.concatenate([x, [2.0 ** -149, 2.0 ** -127, 1.1754942e-38]]
                           ).astype(np.float32)
        exact = 1.0 / np.sqrt(x.astype(np.float64))
        for name, cfg in GATED_CFGS:
            r = np.asarray(dm.rsqrt(jnp.asarray(x), cfg))
            assert np.all(np.isfinite(r)), name
            errs = ulp.ulp_error(r, exact)
            assert errs.max() <= 2.0, (name, errs.max())
            assert errs.max() <= 1.0, (name, errs.max())

    def test_rsqrt_exponent_parity_both_halves(self):
        """Odd and even exponents run different seed-octave folds; both
        halves of the parity stratum must meet the gate independently."""
        x = ulp.sweep_exponent_parity(2048, "float32", seed=3)
        exact = 1.0 / np.sqrt(x.astype(np.float64))
        mask = ulp.oracle_mask(exact) & ulp.oracle_mask(x.astype(np.float64))
        r = np.asarray(dm.rsqrt(jnp.asarray(x), dm.TAYLOR))
        errs = ulp.ulp_error(r, exact, where=mask)
        half = len(x) // 2
        assert errs[:half][mask[:half]].max() <= 1.0   # even exponents
        assert errs[half:][mask[half:]].max() <= 1.0   # odd exponents

    def test_rsqrt_bf16(self):
        """The f32 datapath saturates bf16's 8 mantissa bits."""
        x = np.abs(ulp.sweep_logspace(4096, "bfloat16", seed=2))
        x64 = x.astype(np.float64)
        exact = 1.0 / np.sqrt(x64)
        mask = ulp.oracle_mask(exact, "bfloat16") & ulp.oracle_mask(
            x64, "bfloat16")
        r = np.asarray(dm.rsqrt(jnp.asarray(x), dm.TAYLOR).astype(jnp.float32))
        errs = ulp.ulp_error(r, exact, "bfloat16", where=mask)
        assert errs.max() <= 1.0, errs.max()


def test_rsqrt_ftz_policy_zero_class():
    """Under ftz, subnormal operands are the zero class: +-sub -> +-inf."""
    cfg = dm.DivisionConfig(mode="taylor", underflow="ftz")
    x = jnp.asarray([2.0 ** -127, -(2.0 ** -127), 2.0 ** -149], jnp.float32)
    r = np.asarray(dm.rsqrt(x, cfg))
    assert np.isposinf(r[0]) and np.isneginf(r[1]) and np.isposinf(r[2]), r


def test_rsqrt_grid_cells_present():
    """The conformance grid carries the op=rsqrt column for both dtypes."""
    cells = conformance.default_grid()
    rs = {(c.mode, c.schedule, c.dtype) for c in cells if c.op == "rsqrt"}
    for dt in ("float32", "bfloat16"):
        assert ("exact", "-", dt) in rs
        assert ("taylor", "paper", dt) in rs
        assert ("taylor", "factored", dt) in rs
        assert ("goldschmidt", "-", dt) in rs


def test_rsqrt_cell_runner_gradual_vs_ftz_masks():
    """run_cell measures the subnormal stratum for gradual cells and
    honors the edge contract either way."""
    rep = conformance.run_cell(
        conformance.Cell("taylor", "factored", 2, 24, op="rsqrt"),
        n_log=256, n_man=256)
    assert rep["underflow"] == "gradual"
    assert rep["edge_failures"] == 0
    assert rep["strata"]["subnormals"]["n"] > 0     # measured, not masked
    assert rep["overall"]["max_ulp"] <= 2.0
    assert rep["pass"] is True
    rep = conformance.run_cell(
        conformance.Cell("exact", dtype="float32", op="rsqrt"),
        n_log=256, n_man=256)
    assert rep["underflow"] == "ftz"
    assert rep["edge_failures"] == 0


def test_rsqrt_golden_vectors_unchanged():
    """Committed op=rsqrt golden store: drift fails loudly, by cell name."""
    assert golden.RSQRT_PATH.exists(), (
        "rsqrt golden store missing — run "
        "`python -m repro.eval.golden --generate --store rsqrt`")
    failures = golden.check_rsqrt()
    assert failures == [], failures


def test_rsqrt_oracle_compensated_step(rng):
    """The f64 oracle benefits from the compensated final step too."""
    x = rng.uniform(1e-8, 1e8, 20_000)
    r = taylor.rsqrt_np(x, newton_iters=3)
    assert np.max(np.abs(r * np.sqrt(x) - 1.0)) < 1e-15


@pytest.mark.parametrize("mode", list(dm.MODES))
def test_rsqrt_edges_every_mode(mode):
    """±0 -> ±inf, +inf -> +0, negatives and nan -> nan, in every mode."""
    x64 = np.asarray([0.0, -0.0, np.inf, -np.inf, np.nan, -1.0], np.float64)
    r = np.asarray(dm.rsqrt(jnp.asarray(x64, jnp.float32),
                            dm.DivisionConfig(mode=mode)), np.float64)
    assert conformance._rsqrt_edge_failures(x64, r) == 0, (mode, r)
