"""Eager-vs-jit numerics drift, pinned instead of footnoted.

ROADMAP has long carried the note that the jnp Goldschmidt twin moves a
couple of integer ULPs between eager and jit execution (XLA contracts
``n + n*r`` into an FMA under jit) while the fused kernel matches the
*jit'd* twin bit-for-bit. This module turns both observations into tier-1
regressions: silent contraction widening now fails here instead of living
only as a prose caveat.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.core import goldschmidt, taylor
from repro.core.seeds import compute_segments
from repro.eval import golden, ulp

T24 = compute_segments(2, 24)
GS_ITERS = goldschmidt.iters_for_terms(2)


@pytest.fixture(scope="module")
def corpus():
    """Deterministic paired corpus incl. ratio straddles, edges, subnormals."""
    return golden.golden_div_inputs()


def test_goldschmidt_divide_eager_vs_jit_within_2_int_ulp(corpus):
    """FMA contraction may move the joint N/D recurrence, but never by more
    than 2 integer ULPs — silent widening fails tier-1 here."""
    a, b = corpus
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    qe = np.asarray(goldschmidt.divide(aj, bj, T24, iters=GS_ITERS))
    qj = np.asarray(jax.jit(
        lambda x, y: goldschmidt.divide(x, y, T24, iters=GS_ITERS))(aj, bj))
    d = ulp.ulp_diff(qe, qj)
    assert d.max() <= 2, (int(d.max()),
                          [(float(a[i]), float(b[i]))
                           for i in np.argsort(d)[-3:]])


def test_goldschmidt_recip_eager_vs_jit_within_2_int_ulp():
    x = golden.golden_inputs()
    xj = jnp.asarray(x)
    re = np.asarray(goldschmidt.reciprocal(xj, T24, iters=GS_ITERS))
    rj = np.asarray(jax.jit(
        lambda v: goldschmidt.reciprocal(v, T24, iters=GS_ITERS))(xj))
    assert ulp.ulp_diff(re, rj).max() <= 2


def test_taylor_divide_eager_vs_jit_within_2_int_ulp(corpus):
    """The Dekker residual is FMA-robust by construction; the Taylor twin
    must not drift more than the Goldschmidt bound either."""
    a, b = corpus
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    for sched in ("paper", "factored"):
        qe = np.asarray(taylor.divide(aj, bj, T24, schedule=sched))
        qj = np.asarray(jax.jit(
            lambda x, y, s=sched: taylor.divide(x, y, T24, schedule=s))(aj, bj))
        assert ulp.ulp_diff(qe, qj).max() <= 2, sched


@pytest.mark.parametrize("mode,twin", [
    ("goldschmidt_pallas",
     lambda x, y: goldschmidt.divide(x, y, T24, iters=GS_ITERS,
                                     underflow="ftz")),
    ("taylor_pallas",
     lambda x, y: taylor.divide(x, y, T24, schedule="factored",
                                underflow="ftz")),
])
def test_fused_kernel_bit_identical_to_jit_twin(corpus, mode, twin):
    """The fused kernel matches the *jit'd* ftz twin bit-for-bit (the
    kernel body is traced/compiled, so it sees jit's contraction, not
    eager's) — any divergence means kernel and twin datapaths forked."""
    a, b = corpus
    aj, bj = jnp.asarray(a), jnp.asarray(b)
    qk = np.asarray(dm.div(aj, bj, dm.DivisionConfig(mode=mode)))
    qt = np.asarray(jax.jit(twin)(aj, bj))
    d = ulp.ulp_diff(qk, qt)
    assert d.max() == 0, (mode, int(d.max()))
