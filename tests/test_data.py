"""Data pipeline: determinism, host sharding, learnable structure."""
import numpy as np

from repro.data import DataConfig, SyntheticLM


def test_deterministic_and_seekable():
    cfg = DataConfig(vocab=1000, seq_len=64, global_batch=8, seed=7)
    d1 = SyntheticLM(cfg)
    d2 = SyntheticLM(cfg)
    for step in (0, 5, 1000):
        b1, b2 = d1.batch(step), d2.batch(step)
        assert np.array_equal(b1["tokens"], b2["tokens"])
        assert np.array_equal(b1["labels"], b2["labels"])
    assert not np.array_equal(d1.batch(1)["tokens"], d1.batch(2)["tokens"])


def test_labels_shifted():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    b = SyntheticLM(cfg).batch(0)
    assert b["tokens"].shape == (2, 16)
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_disjoint_and_covering():
    cfg = DataConfig(vocab=500, seq_len=32, global_batch=8, seed=3)
    hosts = [SyntheticLM(cfg, host_index=i, host_count=4) for i in range(4)]
    batches = [h.batch(12)["tokens"] for h in hosts]
    assert all(b.shape == (2, 32) for b in batches)
    # different hosts produce different rows (independent streams)
    for i in range(4):
        for j in range(i + 1, 4):
            assert not np.array_equal(batches[i], batches[j])


def test_prefetch_iterator_matches_batches():
    cfg = DataConfig(vocab=200, seq_len=16, global_batch=2)
    d = SyntheticLM(cfg)
    it = d.iter(start_step=3)
    for step in (3, 4, 5):
        got = next(it)
        want = d.batch(step)
        assert np.array_equal(got["tokens"], want["tokens"])


def test_motifs_make_data_learnable():
    """Consecutive-token motifs exist: P(next == cur+1) is well above chance."""
    cfg = DataConfig(vocab=1000, seq_len=256, global_batch=4)
    b = SyntheticLM(cfg).batch(0)
    t = b["tokens"]
    frac = np.mean(t[:, 1:] == t[:, :-1] + 1)
    assert frac > 0.05  # chance level would be ~1/1000
