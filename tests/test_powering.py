"""Paper §5-6: powering unit schedule + squaring-unit hardware claim."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import powering


class TestSchedule:
    @given(st.integers(2, 33))
    @settings(max_examples=32, deadline=None)
    def test_produces_exact_powers(self, n):
        x = 0.9371
        powers = powering.eval_powers(x, n, mul=lambda a, b: a * b,
                                      square=lambda a: a * a)
        for k in range(2, n + 1):
            assert abs(powers[k] - x**k) < 1e-12 * max(1, x**k)

    @given(st.integers(2, 33))
    @settings(max_examples=32, deadline=None)
    def test_even_powers_only_use_squarer(self, n):
        for kind, src, dst in powering.schedule(n):
            if dst % 2 == 0:
                assert kind == "square"
            else:
                assert kind == "mul"
                a, b = src
                assert a == 1 and b == dst - 1  # odd = x * previous even (§6)

    def test_two_terms_per_cycle(self):
        # §6: after x^2, each cycle yields one odd (mul) + one even (square)
        ops = powering.schedule(12)
        assert ops[0] == ("square", 1, 2)
        produced = [dst for _, _, dst in ops]
        assert produced == [2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]

    def test_op_counts_factored_wins_from_n5(self):
        """Beyond-paper factored schedule: for n >= 5 (the paper's operating
        point) it never uses more ops or cycles than the §6 schedule, always
        covers at least as many series terms, and wins strictly for n >= 6.
        (At n in {2,4} the §6 schedule is cheaper — recorded trade-off.)"""
        for n in (3, 5, 7, 9, 12, 17, 33):
            p = powering.op_counts(n, "paper")
            f = powering.op_counts(n, "factored")
            assert f["mul"] + f["square"] <= p["mul"] + p["square"]
            assert f["terms"] >= p["terms"]
            assert f["cycles"] <= p["cycles"]
        # strict win at larger n
        p17 = powering.op_counts(17, "paper")
        f17 = powering.op_counts(17, "factored")
        assert f17["mul"] + f17["square"] < p17["mul"] + p17["square"]


class TestHwCost:
    def test_squarer_under_half(self):
        hw = powering.hw_cost()
        assert hw["area_ratio"] < 0.5       # paper §5 headline claim
        assert hw["unit_ratio"] < 0.5
        m, s = hw["multiplier"], hw["squarer"]
        assert m.priority_encoder == 2 * s.priority_encoder
        assert m.lod == 2 * s.lod
        assert s.decoder == 0               # 4^k is (100)_2 << k, no decoder
