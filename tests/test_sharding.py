"""Sharding rules: divisibility/duplicate drops + an 8-device SPMD subprocess."""
import subprocess
import sys

import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config, rules_for
from repro.sharding import rules as shr


from repro.launch.mesh import _axis_type_kwargs as _axis_kwargs


def _mesh11():
    return jax.make_mesh((1, 1), ("data", "model"), **_axis_kwargs(2))


class TestSpecFor:
    def test_divisibility_drop(self):
        mesh = _mesh11()

        class FakeMesh:
            shape = {"data": 16, "model": 16}

        rules = {"heads": "model", "kv_heads": "model", "embed": None}
        # 32 heads shard; 8 kv heads don't divide 16 -> dropped
        s = shr.spec_for((4096, 32, 128), ("embed", "heads", "head_dim"),
                         rules, FakeMesh)
        assert s == P(None, "model", None)
        s = shr.spec_for((4096, 8, 128), ("embed", "kv_heads", "head_dim"),
                         rules, FakeMesh)
        assert s == P(None, None, None)

    def test_duplicate_axis_drop(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}

        rules = {"experts": "data", "embed": "data", "expert_mlp": "model"}
        s = shr.spec_for((16, 8192, 24576), ("experts", "embed", "expert_mlp"),
                         rules, FakeMesh)
        assert s == P("data", None, "model")  # embed's 'data' was taken

    def test_jamba_rules_fully_shard_experts(self):
        cfg = get_config("jamba_1_5_large")
        rules = rules_for(cfg)

        class FakeMesh:
            shape = {"pod": 2, "data": 16, "model": 16}

        s = shr.spec_for((36, 16, 8192, 24576),
                         ("layers", "experts", "embed", "expert_mlp"),
                         rules, FakeMesh)
        assert s == P(None, "data", None, "model")


class TestSpecForDrops:
    """spec_for's silent fallbacks become recorded entries (PR 7 satellite)."""

    def test_drops_recorded_with_reasons(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}

        rules = {"experts": "data", "embed": "data", "kv_heads": "model",
                 "seq": "pod"}
        drops = []
        s = shr.spec_for((16, 8192, 12, 100),
                         ("experts", "embed", "kv_heads", "seq"),
                         rules, FakeMesh, drops=drops)
        assert s == P("data", None, None, None)
        reasons = {d["dim"]: d["reason"] for d in drops}
        assert reasons == {1: "duplicate", 2: "indivisible",
                           3: "missing-axis"}
        kv = next(d for d in drops if d["dim"] == 2)
        assert kv["logical_axis"] == "kv_heads"
        assert kv["mesh_axis"] == "model"
        assert kv["dim_size"] == 12 and kv["mesh_axis_size"] == 16

    def test_intended_replication_is_not_a_drop(self):
        class FakeMesh:
            shape = {"data": 16, "model": 16}

        drops = []
        s = shr.spec_for((4096, 128), ("embed", "head_dim"),
                         {"embed": None}, FakeMesh, drops=drops)
        assert s == P(None, None)
        assert drops == []

    def test_param_fallbacks_names_gqa_kv_replication(self):
        """GQA kv_heads < model axis: the replicated KV tensors must show up
        as named entries with their byte sizes, not vanish."""
        class FakeMesh:
            shape = {"data": 32, "model": 32}

        cfg = get_config("llama3_8b")       # 8 kv heads < model=32
        entries = shr.param_fallbacks(cfg, FakeMesh)
        kv = [e for e in entries if e["reason"] == "indivisible"]
        assert kv, "expected indivisible drops on the 32-wide model axis"
        for e in kv:
            assert e["param"] and e["bytes"] > 0 and len(e["shape"]) >= 2
            assert e["mesh_axis_size"] == 32
            assert e["dim_size"] % 32 != 0


class TestBatchPartition:
    """data_sharding's all-or-nothing fallback is fixed: largest divisible
    prefix of ('pod','data') instead of replicating the whole batch."""

    class PodMesh:
        shape = {"pod": 2, "data": 16, "model": 16}

    def test_regression_batch16_pod2_data16(self):
        # The bug this PR fixes: batch=16 on pod=2 x data=16 used to fall
        # back to fully replicated because 16 % 32 != 0 — but the pod axis
        # alone divides 16, so the batch must shard over ('pod',).
        assert shr.batch_partition(self.PodMesh, 16) == ("pod",)
        assert shr.data_spec(self.PodMesh, 2, batch_size=16) == P("pod", None)

    def test_full_prefix_when_divisible(self):
        assert shr.batch_partition(self.PodMesh, 64) == ("pod", "data")
        assert shr.data_spec(self.PodMesh, 2, batch_size=64) == \
            P(("pod", "data"), None)

    def test_nothing_divides_replicates(self):
        assert shr.batch_partition(self.PodMesh, 7) == ()
        assert shr.data_spec(self.PodMesh, 2, batch_size=7) == P(None, None)

    def test_none_batch_uses_full_prefix(self):
        assert shr.batch_partition(self.PodMesh, None) == ("pod", "data")

    def test_single_pod_mesh(self):
        class M:
            shape = {"data": 16, "model": 16}

        assert shr.batch_partition(M, 48) == ("data",)
        assert shr.batch_partition(M, 10) == ()


class TestMakeHostMesh:
    """make_host_mesh raises ValueError (not a -O-stripped assert)."""

    def test_model_exceeds_device_count(self):
        from repro.launch.mesh import make_host_mesh

        n = jax.device_count()
        with pytest.raises(ValueError, match=f"exceeds the {n} available"):
            make_host_mesh(model=n + 1)

    def test_error_names_force_flag(self):
        from repro.launch.mesh import make_host_mesh

        with pytest.raises(ValueError,
                           match="xla_force_host_platform_device_count"):
            make_host_mesh(model=jax.device_count() + 1)

    def test_model_below_one(self):
        from repro.launch.mesh import make_host_mesh

        with pytest.raises(ValueError, match="must be >= 1"):
            make_host_mesh(model=0)

    def test_indivisible_names_device_count(self):
        from repro.launch.mesh import make_host_mesh

        n = jax.device_count()
        if n < 3:
            pytest.skip("needs >= 3 devices for an indivisible case")
        model = next(m for m in range(2, n) if n % m)
        with pytest.raises(ValueError, match=f"device count {n}"):
            make_host_mesh(model=model)

    def test_valid_mesh_still_builds(self):
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh(model=1)
        assert mesh.shape["model"] == 1
        assert mesh.shape["data"] == jax.device_count()


def test_param_shardings_all_valid():
    """Every param's spec must divide its dims on the production mesh shape."""
    class FakeMesh:
        shape = {"data": 16, "model": 16}

    from repro.configs import ARCH_IDS
    from repro.models.params import model_specs, ParamSpec

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        rules = rules_for(cfg)
        specs = model_specs(cfg)
        leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, ParamSpec))
        for p in leaves:
            s = shr.spec_for(p.shape, p.axes, rules, FakeMesh)
            for dim, part in zip(p.shape, s):
                if part is not None:
                    assert dim % FakeMesh.shape[part] == 0


SUBPROC_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
import dataclasses
from repro.configs import get_smoke_config
from repro.models import init_params, abstract_params
from repro.sharding import rules as shr
from repro.optim import adamw
from repro.train import step as ts

cfg = dataclasses.replace(get_smoke_config("llama3_8b"))
from repro.launch.mesh import _axis_type_kwargs as _axis_kwargs
mesh = jax.make_mesh((4, 2), ("data", "model"), **_axis_kwargs(2))
params = init_params(cfg, jax.random.PRNGKey(0))
pshard = shr.param_shardings(cfg, mesh)
params = jax.device_put(params, pshard)
opt_cfg = adamw.AdamWConfig(division=cfg.division)
state = ts.init_state(cfg, params, opt_cfg)
batch = {"tokens": jnp.zeros((8, 32), jnp.int32),
         "labels": jnp.zeros((8, 32), jnp.int32)}
bshard = {k: shr.data_sharding(mesh, 2, batch_size=8) for k in batch}
batch = jax.device_put(batch, bshard)
with mesh:
    new_state, metrics = jax.jit(
        lambda s, b: ts.train_step(cfg, opt_cfg, s, b, n_micro=2))(state, batch)
loss = float(metrics["loss"])
assert loss > 0 and loss == loss, loss

# --- elastic resume: checkpoint under (4,2), restore under (2,4) ---
import tempfile, numpy as np
from repro.train import checkpoint as ck
with tempfile.TemporaryDirectory() as d:
    ck.save(d, 1, new_state)
    mesh2 = jax.make_mesh((2, 4), ("data", "model"), **_axis_kwargs(2))
    pshard2 = shr.param_shardings(cfg, mesh2)
    state_shard2 = ts.TrainState(
        params=pshard2,
        opt=type(new_state.opt)(
            step=jax.NamedSharding(mesh2, P()) if hasattr(jax, "NamedSharding")
            else jax.sharding.NamedSharding(mesh2, P()),
            m=pshard2, v=pshard2),
        step=jax.sharding.NamedSharding(mesh2, P()))
    _, restored = ck.restore_latest(d, new_state, shardings=state_shard2)
    a = np.asarray(jax.device_get(jax.tree_util.tree_leaves(new_state.params)[0]))
    b = np.asarray(jax.device_get(jax.tree_util.tree_leaves(restored.params)[0]))
    assert np.array_equal(a, b), "elastic restore changed values"
    lf = jax.tree_util.tree_leaves(restored.params)[0]
    assert lf.sharding.mesh.shape["model"] == 4, "not resharded to new mesh"
print("SPMD8 OK", loss)
"""


def test_real_8device_spmd_training():
    """Real multi-device data+tensor parallel train step (subprocess: device
    count must be set before jax initializes)."""
    r = subprocess.run([sys.executable, "-c", SUBPROC_SNIPPET],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ,
                            "PYTHONPATH": "src"},
                       cwd="/root/repo")
    assert "SPMD8 OK" in r.stdout, r.stdout + r.stderr


COMPRESS_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim import compress

from repro.launch.mesh import _axis_type_kwargs as _axis_kwargs
mesh = jax.make_mesh((2, 4), ("pod", "data"), **_axis_kwargs(2))
g = jnp.asarray(np.random.default_rng(0).normal(size=(2, 4, 64)), jnp.float32)
err = jnp.zeros_like(g)

def body(g_blk, e_blk):
    mean, new_err = compress.psum_compressed(g_blk, e_blk, "pod")
    return mean, new_err

f = shard_map(body, mesh=mesh, in_specs=(P("pod", "data"), P("pod", "data")),
              out_specs=(P("pod", "data"), P("pod", "data")))
mean, new_err = jax.jit(f)(g, err)
# cross-pod mean: both pods see the same mean; check vs exact
exact = (g[0] + g[1]) / 2
got = np.asarray(mean)[0]
lsb = float(jnp.max(jnp.abs(g))) / 127
assert np.max(np.abs(got - np.asarray(exact))) <= lsb + 1e-6, "int8 mean off"
# pods agree
assert np.allclose(np.asarray(mean)[0], np.asarray(mean)[1])
print("COMPRESS8 OK")
"""


def test_int8_compressed_psum_on_pod_axis():
    """int8 error-feedback gradient compression across a real 'pod' axis."""
    r = subprocess.run([sys.executable, "-c", COMPRESS_SNIPPET],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ, "PYTHONPATH": "src"},
                       cwd="/root/repo")
    assert "COMPRESS8 OK" in r.stdout, r.stdout + r.stderr
