"""core/goldschmidt.py: oracle precision, Taylor equivalence, joint divide."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import goldschmidt, taylor
from repro.core.seeds import compute_segments


class TestItersDial:
    def test_iters_for_terms(self):
        # 2^j >= n+1: n=1 -> 1, n=2..3 -> 2, n=4..7 -> 3, n=17 -> 5
        assert goldschmidt.iters_for_terms(1) == 1
        assert goldschmidt.iters_for_terms(2) == 2
        assert goldschmidt.iters_for_terms(3) == 2
        assert goldschmidt.iters_for_terms(7) == 3
        assert goldschmidt.iters_for_terms(17) == 5


class TestOracle:
    def test_quadratic_convergence(self, rng):
        """Each iteration squares the residual: error ~ m_max^(2^j)."""
        t = compute_segments(5, 53)
        x = rng.uniform(1.0, 2.0, 50_000)
        prev = None
        for iters in (1, 2, 3):
            r = goldschmidt.reciprocal_np(x, t, iters=iters)
            err = np.max(np.abs(r * x - 1.0))
            if prev is not None and prev > 1e-14:
                # quadratic until the f64 evaluation-rounding floor
                assert err <= max(prev * prev * 4.0, 2**-50)
            prev = err
        assert prev < 2**-50

    def test_matches_factored_taylor_algebra(self, rng):
        """j Goldschmidt iterations == factored Taylor covering 2^j terms
        (identical product, different evaluation order -> f64-rounding close)."""
        t = compute_segments(5, 53)
        x = rng.uniform(1.0, 2.0, 20_000)
        rg = goldschmidt.reciprocal_np(x, t, iters=2)
        rf = taylor.reciprocal_np(x, t, n_iters=3, schedule="factored")
        np.testing.assert_allclose(rg, rf, rtol=1e-14)

    def test_divide_oracle(self, rng):
        a = rng.normal(size=10_000) * 100
        b = rng.uniform(0.5, 100, 10_000)
        q = goldschmidt.divide_np(a, b, iters=3)
        assert np.max(np.abs(q - a / b) / np.abs(a / b + 1e-30)) < 2**-49


class TestJnp:
    def test_full_range(self, rng):
        t = compute_segments(2, 24)
        x = jnp.asarray(rng.uniform(0.01, 1000, 50_000), jnp.float32)
        r = jax.jit(lambda v: goldschmidt.reciprocal(v, t))(x)
        rel = np.abs(np.asarray(r) * np.asarray(x) - 1.0)
        assert rel.max() < 2**-22

    def test_divide_no_intermediate_underflow(self):
        """Joint mantissa refinement: q is fine even where recip(b) would
        be subnormal/flushed — the failure mode of a*recip(b) divides."""
        a = jnp.asarray([2.0**100, 2.0**120], jnp.float32)
        b = jnp.asarray([2.0**127, 2.0**127], jnp.float32)
        q = np.asarray(goldschmidt.divide(a, b, iters=2))
        expect = np.asarray([2.0**-27, 2.0**-7])
        np.testing.assert_allclose(q, expect, rtol=1e-6)

    def test_bf16_passthrough(self, rng):
        x = jnp.asarray(rng.uniform(0.1, 10, 4096), jnp.bfloat16)
        r = goldschmidt.reciprocal(x)
        rel = np.abs(np.asarray(r, np.float32) * np.asarray(x, np.float32) - 1)
        assert rel.max() < 0.02

    def test_grad(self):
        g = jax.grad(lambda v: goldschmidt.reciprocal(v).sum())(jnp.float32(2.0))
        assert abs(float(g) + 0.25) < 1e-5
        ga, gb = jax.grad(lambda a, b: goldschmidt.divide(a, b).sum(),
                          argnums=(0, 1))(jnp.float32(6.0), jnp.float32(3.0))
        assert abs(float(ga) - 1 / 3) < 1e-5
        assert abs(float(gb) + 2 / 3) < 1e-5
