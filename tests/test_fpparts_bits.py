"""Property suite for the bit-level f32 datapath (core/fpparts.py).

The tentpole invariants, hypothesis-style with pinned replays:

  (a) split_f32 -> repack_f32 is the *identity* on every finite f32 bit
      pattern — subnormals, signed zeros and extremes included;
  (b) the RNE repack agrees bit-for-bit with numpy's correctly-rounded
      f64 -> f32 cast on subnormal-range targets;
  (c) algebraic divide invariants in every non-ILM mode: exact sign
      antisymmetry div(-a, b) == -div(a, b), and exact power-of-two
      scaling div(ldexp(a, k), b) == ldexp(div(a, b), k) away from the
      under/overflow cliffs (both are exponent/sign bookkeeping only — the
      mantissa datapath must be oblivious to them).
"""
import numpy as np
import pytest

import jax.numpy as jnp
from jax import lax

from _hypothesis_compat import given, settings, st
from repro.core import division_modes as dm
from repro.core import fpparts

NON_ILM_MODES = ["exact", "taylor", "taylor_pallas",
                 "goldschmidt", "goldschmidt_pallas"]

# Pinned bit patterns: signed zeros, min/max subnormal, min/max normal,
# mid-range, halfway-rounding mantissas, and the subnormal boundary.
PINNED_BITS = [
    0x0000_0000, 0x8000_0000,             # +-0
    0x0000_0001, 0x8000_0001,             # +-min subnormal (2^-149)
    0x007F_FFFF, 0x807F_FFFF,             # +-max subnormal
    0x0080_0000, 0x8080_0000,             # +-min normal (2^-126)
    0x7F7F_FFFF, 0xFF7F_FFFF,             # +-max finite
    0x3F80_0000, 0x4000_0000,             # 1.0, 2.0
    0x0040_0000, 0x0000_0002,             # 2^-127, 2^-148
    0x3F80_0001, 0x3FFF_FFFF,             # 1.0+ulp, just under 2
]


def _roundtrip_bits(bits_u32: np.ndarray) -> np.ndarray:
    """split -> repack of the given f32 bit patterns, returning bits."""
    x = jnp.asarray(bits_u32).view(jnp.float32)
    b = lax.bitcast_convert_type(x, jnp.uint32)
    mag = b & fpparts.F32_MAG_MASK
    man, e = fpparts.split_f32(mag)
    back = fpparts.repack_f32(jnp.where(man == 0, jnp.float32(1.0), man), e,
                              b & fpparts.F32_SIGN)
    back = jnp.where(man == 0,
                     lax.bitcast_convert_type(b & fpparts.F32_SIGN,
                                              jnp.float32), back)
    return np.asarray(back).view(np.uint32)


def test_split_repack_identity_pinned():
    bits = np.asarray(PINNED_BITS, np.uint32)
    got = _roundtrip_bits(bits)
    mism = got != bits
    assert not mism.any(), [hex(b) for b in bits[mism]]


@settings(max_examples=64, deadline=None)
@given(st.integers(0, 2**32 - 1))
def test_split_repack_identity_random_bits(pattern):
    bits = np.asarray([pattern], np.uint32)
    if not np.isfinite(bits.view(np.float32))[0]:
        return                     # inf/nan: discarded by the edge overrides
    got = _roundtrip_bits(bits)
    assert got[0] == bits[0], hex(int(bits[0]))


def test_split_repack_identity_dense_subnormals():
    """Every 97th subnormal bit pattern plus both boundary neighborhoods."""
    bits = np.concatenate([
        np.arange(1, 0x0080_0000, 97, dtype=np.uint32),
        np.arange(0x007F_FFF0, 0x0080_0010, dtype=np.uint32),
    ])
    bits = np.concatenate([bits, bits | fpparts.F32_SIGN])
    got = _roundtrip_bits(bits)
    np.testing.assert_array_equal(got, bits)


@settings(max_examples=64, deadline=None)
@given(st.floats(1.0, 1.9999999), st.integers(-152, -120))
def test_repack_rne_matches_numpy_cast(man, e):
    """Subnormal-range repack == numpy's correctly-rounded f64 -> f32 cast."""
    manf = np.float32(man)
    got = np.asarray(fpparts.repack_f32(
        jnp.asarray([manf]), jnp.asarray([e], jnp.int32),
        jnp.zeros(1, jnp.uint32)))
    want = np.asarray([np.float64(manf) * 2.0 ** e]).astype(np.float32)
    assert got.view(np.uint32)[0] == want.view(np.uint32)[0], (man, e, got, want)


def test_repack_ftz_flushes_after_rounding():
    """FTZ flushes results still subnormal *after* RNE — a carry that rounds
    up to the smallest normal must survive (the hardware tininess rule)."""
    man = jnp.asarray([1.9999999, 1.5], jnp.float32)
    e = jnp.asarray([-127, -130], jnp.int32)
    got = np.asarray(fpparts.repack_f32(man, e, jnp.zeros(2, jnp.uint32),
                                        underflow="ftz"))
    assert got[0] == np.float32(2.0 ** -126), got   # rounded up to normal
    assert got[1] == 0.0, got                       # still subnormal: flushed


# ------------------------------------------------- algebraic divide invariants

PINNED_PAIRS = [
    (1.5, 3.0), (2.0 ** -100, 7.0), (1.0, 2.0 ** 100),
    (1.9999999, 1.0000001), (3.0, 2.0 ** -60),
]


@pytest.mark.parametrize("mode", NON_ILM_MODES)
def test_div_sign_antisymmetry_bitwise(mode):
    """div(-a, b) == -div(a, b) bit-for-bit: the sign never enters the
    mantissa datapath (it is a single xor in hardware)."""
    rng = np.random.default_rng(7)
    a = np.concatenate([[p[0] for p in PINNED_PAIRS],
                        np.ldexp(rng.uniform(1, 2, 59),
                                 rng.integers(-120, 121, 59))]).astype(np.float32)
    b = np.concatenate([[p[1] for p in PINNED_PAIRS],
                        np.ldexp(rng.uniform(1, 2, 59),
                                 rng.integers(-120, 121, 59))]).astype(np.float32)
    cfg = dm.DivisionConfig(mode=mode)
    q_pos = np.asarray(dm.div(jnp.asarray(a), jnp.asarray(b), cfg))
    q_neg = np.asarray(dm.div(jnp.asarray(-a), jnp.asarray(b), cfg))
    np.testing.assert_array_equal(q_neg.view(np.uint32),
                                  (-q_pos).view(np.uint32), err_msg=mode)


@settings(max_examples=20, deadline=None)
@given(st.floats(1.0, 1.9999999), st.floats(1.0, 1.9999999),
       st.integers(-30, 30), st.integers(-40, 40))
def test_div_pow2_scaling_invariance(ma, mb, eb, k):
    """div(ldexp(a, k), b) == ldexp(div(a, b), k) bitwise, away from cliffs.

    Power-of-two scalings only move the exponent field; both sides round
    the same mantissa quotient once, so they must agree exactly for every
    jnp mode (and exact XLA).
    """
    a = np.float32(ma)                    # quotient exponent in [-1, 1]
    b = np.float32(np.ldexp(mb, eb))
    ak = np.float32(np.ldexp(ma, k))      # scaled operand, still mid-range
    for mode in ("exact", "taylor", "goldschmidt"):
        cfg = dm.DivisionConfig(mode=mode)
        q = np.asarray(dm.div(jnp.asarray([a]), jnp.asarray([b]), cfg))
        qk = np.asarray(dm.div(jnp.asarray([ak]), jnp.asarray([b]), cfg))
        want = np.ldexp(q.astype(np.float64), k).astype(np.float32)
        assert qk.view(np.uint32)[0] == want.view(np.uint32)[0], (
            mode, ma, mb, eb, k, qk, want)


@pytest.mark.parametrize("mode", ["taylor_pallas", "goldschmidt_pallas"])
def test_div_pow2_scaling_invariance_pallas(mode):
    """Same invariance through the fused kernels, batched (one launch)."""
    rng = np.random.default_rng(11)
    n = 64
    ma = rng.uniform(1, 2, n)
    mb = rng.uniform(1, 2, n)
    eb = rng.integers(-30, 31, n)
    k = rng.integers(-40, 41, n)
    a = ma.astype(np.float32)
    b = np.ldexp(mb, eb).astype(np.float32)
    ak = np.ldexp(ma, k).astype(np.float32)
    cfg = dm.DivisionConfig(mode=mode)
    q = np.asarray(dm.div(jnp.asarray(a), jnp.asarray(b), cfg))
    qk = np.asarray(dm.div(jnp.asarray(ak), jnp.asarray(b), cfg))
    want = np.ldexp(q.astype(np.float64), k).astype(np.float32)
    np.testing.assert_array_equal(qk.view(np.uint32), want.view(np.uint32),
                                  err_msg=mode)
