"""CLI exit codes + degenerate-operand robustness for the public surface.

  (a) ``repro.eval.conformance`` exits non-zero when any grid cell fails
      its gate (edge-contract violation or a blown eq. 17 bound), so CI
      can consume the run directly;
  (b) ``repro.eval.golden --check`` exits non-zero on drift or a missing
      store, for every store including the new rsqrt one;
  (c) every public op (recip / div / rsqrt / softmax) accepts empty,
      rank-0, and bf16 scalar operands in every mode without crashing —
      extending the PR 3 empty-operand fix beyond divide.
  (d) ``repro.launch.serve`` routes ``--batch`` through the batched path,
      honours the division-mode flags, and rejects unknown modes.
"""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.eval import conformance, golden


def _run_cli(args, timeout=600):
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", *args],
        capture_output=True, text=True, timeout=timeout,
        env={**os.environ, "PYTHONPATH": "src"}, cwd="/root/repo")


# ------------------------------------------------------------- exit codes

def _fake_cell(**over):
    cell = {
        "op": "recip", "mode": "taylor", "schedule": "factored",
        "n_iters": 2, "precision_bits": 24, "dtype": "float32",
        "key": "recip/taylor/factored/n2p24/float32", "underflow": "gradual",
        "overall": {"max_ulp": 0.5, "mean_ulp": 0.2, "p99_ulp": 0.4, "n": 10},
        "strata": {}, "edge_failures": 0, "seconds": 0.0,
    }
    cell.update(over)
    cell["pass"] = conformance.cell_gate(cell)
    return cell


def test_cell_gate_verdicts():
    assert _fake_cell()["pass"] is True
    assert _fake_cell(edge_failures=3)["pass"] is False
    assert _fake_cell(overall={"max_ulp": 3.0, "mean_ulp": 1.0,
                               "p99_ulp": 2.0, "n": 10})["pass"] is False
    # The loose end of the dial and ILM are not ULP-gated.
    assert _fake_cell(n_iters=1, overall={"max_ulp": 4000.0, "mean_ulp": 9.0,
                                          "p99_ulp": 100.0, "n": 10})["pass"]
    assert _fake_cell(mode="ilm", overall={"max_ulp": 1e4, "mean_ulp": 100.0,
                                           "p99_ulp": 1e3, "n": 10})["pass"]
    assert _fake_cell(overall={"max_ulp": float("inf"), "mean_ulp": 0.1,
                               "p99_ulp": 0.1, "n": 10})["pass"] is False


def test_conformance_main_exit_codes(monkeypatch, capsys):
    def fake_run(cells=None, quick=False, seed=0, **kw):
        return {"meta": {}, "cells": [_fake_cell()]}

    monkeypatch.setattr(conformance, "run_conformance", fake_run)
    assert conformance.main(["--quick"]) == 0

    def fake_run_bad(cells=None, quick=False, seed=0, **kw):
        return {"meta": {}, "cells": [_fake_cell(), _fake_cell(edge_failures=1)]}

    monkeypatch.setattr(conformance, "run_conformance", fake_run_bad)
    assert conformance.main(["--quick"]) == 1
    out = capsys.readouterr().out
    assert "CONFORMANCE FAILURES" in out


def test_golden_main_nonzero_on_failure(monkeypatch, capsys):
    monkeypatch.setattr(golden, "check_rsqrt",
                        lambda **kw: [{"cell": "rsqrt/taylor/newton2",
                                       "n_mismatch": 1, "max_ulp_drift": 7}])
    assert golden.main(["--check", "--store", "rsqrt"]) == 1
    assert "GOLDEN-VECTOR REGRESSION" in capsys.readouterr().out


def test_golden_check_missing_store_fails(tmp_path):
    """Every store reports a missing file as a named failure (exit 1 via
    main), never an unhandled exception."""
    for fn in (golden.check, golden.check_divide, golden.check_rsqrt):
        failures = fn(path=tmp_path / "nope.npz")
        assert failures and "missing" in failures[0]["error"], fn.__name__


def test_golden_store_choices_include_rsqrt(capsys):
    with pytest.raises(SystemExit):
        golden.main(["--check", "--store", "bogus"])
    capsys.readouterr()


# ------------------------------------------------------------- serve CLI

def test_serve_cli_single_path():
    r = _run_cli(["--arch", "paper_fpdiv", "--smoke", "--batch", "1",
                  "--prompt-len", "12", "--max-new", "4"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "generated 4 tokens" in r.stdout
    assert "tok/s" in r.stdout
    assert "division=taylor" in r.stdout  # config default


def test_serve_cli_batched_with_division_flags():
    r = _run_cli(["--arch", "paper_fpdiv", "--smoke", "--batch", "3",
                  "--prompt-len", "14", "--max-new", "4",
                  "--division-mode", "goldschmidt", "--n-iters", "3",
                  "--schedule", "factored"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "division=goldschmidt" in r.stdout
    assert "n_iters=3" in r.stdout
    assert r.stdout.count("generated 4 tokens") == 3  # the batched path ran


def test_serve_cli_rejects_unknown_mode():
    r = _run_cli(["--arch", "paper_fpdiv", "--smoke",
                  "--division-mode", "bogus"], timeout=120)
    assert r.returncode != 0
    assert "invalid choice" in r.stderr


# ---------------------------------------------- degenerate-operand matrix

DEGENERATE = [
    ("empty", lambda: jnp.zeros((0,), jnp.float32)),
    ("empty2d", lambda: jnp.zeros((2, 0), jnp.float32)),
    ("rank0_f32", lambda: jnp.float32(2.5)),
    ("rank0_bf16", lambda: jnp.bfloat16(2.5)),
]


@pytest.mark.parametrize("mode", list(dm.MODES))
@pytest.mark.parametrize("case,make", DEGENERATE)
def test_public_ops_accept_degenerate_operands(mode, case, make):
    """recip/div/rsqrt/softmax: empty, rank-0 and bf16 scalars round-trip
    shape and dtype in every mode (no kernel launch on zero lanes, no
    reduction over an empty softmax axis, no rank assumptions)."""
    cfg = dm.DivisionConfig(mode=mode)
    x = make()
    r = dm.recip(x, cfg)
    assert r.shape == x.shape and r.dtype == x.dtype
    q = dm.div(x, x, cfg)
    assert q.shape == x.shape and q.dtype == x.dtype
    s = dm.rsqrt(x, cfg)
    assert s.shape == x.shape and s.dtype == x.dtype
    sm = dm.softmax(x, cfg=cfg)
    assert sm.shape == x.shape and sm.dtype == x.dtype


def test_degenerate_values_are_sane():
    """Beyond not crashing: rank-0 results carry the right values."""
    for mode in ("taylor", "taylor_pallas", "goldschmidt", "exact"):
        cfg = dm.DivisionConfig(mode=mode)
        assert abs(float(dm.recip(jnp.float32(4.0), cfg)) - 0.25) < 1e-6
        assert abs(float(dm.div(jnp.float32(6.0), jnp.float32(3.0), cfg))
                   - 2.0) < 1e-6
        assert abs(float(dm.rsqrt(jnp.float32(4.0), cfg)) - 0.5) < 1e-6
        assert float(dm.softmax(jnp.float32(3.0), cfg=cfg)) == 1.0
        bf = dm.div(jnp.bfloat16(1.0), jnp.bfloat16(3.0), cfg)
        assert bf.dtype == jnp.bfloat16
        assert abs(float(bf) - 1 / 3) < 0.01
