"""Division-powered workloads (repro.workloads) + the tiled divide kernel.

Gates:
  (a) K-Means inertia matches the XLA-exact twin (identical inits) for every
      non-ILM mode, batched shapes included;
  (b) Givens QR passes orthogonality / reconstruction / triangularity
      residual gates in both coefficient formulations (div and rsqrt);
  (c) rank-2 operands dispatch to the *tiled* fused divide kernel — never
      the flatten-pad path, never the jnp fallback — including shapes that
      are not multiples of the (8, 128) tile (ragged last tiles);
  (d) the tiled kernel is bit-identical to the pre-padded kernel where both
      apply, honors the IEEE edge contract, and carries the analytic VJP;
  (e) gradients flow through the workloads (the frexp/bitcast datapaths
      silently zero cotangents unless attach_grad / custom_vjp is wired).
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import division_modes as dm
from repro.eval import workload_metrics as wm
from repro.workloads import kmeans as km
from repro.workloads import qr as qrw

# Every mode except ilm (whose ~12-bit mantissa is out of tolerance by
# design) on the default n=2 @ 24-bit operating point.
NON_ILM = [
    ("exact", "-"),
    ("taylor", "paper"),
    ("taylor", "factored"),
    ("taylor_pallas", "factored"),
    ("goldschmidt", "-"),
    ("goldschmidt_pallas", "-"),
]


def _cfg(mode, sched):
    return dm.DivisionConfig(
        mode=mode, schedule=sched if sched != "-" else "factored")


@pytest.fixture(scope="module")
def blobs():
    x = km.make_blobs(jax.random.PRNGKey(3), 256, 8, 4)
    init = jnp.take(x, jnp.arange(4) * 61, axis=0)
    return x, init


@pytest.fixture(scope="module")
def exact_kmeans(blobs):
    x, init = blobs
    return km.kmeans(x, cfg=dm.EXACT, init=init, n_iters=6)


# ------------------------------------------------------------------ K-Means

@pytest.mark.parametrize("mode,sched", NON_ILM)
def test_kmeans_inertia_matches_exact_twin(blobs, exact_kmeans, mode, sched):
    x, init = blobs
    res = km.kmeans(x, cfg=_cfg(mode, sched), init=init, n_iters=6)
    delta = wm.relative_delta(res.inertia, exact_kmeans.inertia)
    assert delta <= 1e-4, (mode, sched, delta)
    # The clustering itself should agree, not just the objective.
    agree = float(jnp.mean(
        (res.assignments == exact_kmeans.assignments).astype(jnp.float32)))
    assert agree >= 0.99, (mode, sched, agree)


def test_kmeans_inertia_monotone_trace(blobs):
    x, init = blobs
    res = km.kmeans(x, cfg=dm.TAYLOR, init=init, n_iters=6)
    trace = np.asarray(res.inertia_trace, np.float64)
    assert np.all(np.diff(trace) <= 1e-7), trace  # Lloyd never increases


def test_kmeans_batched(blobs):
    x, init = blobs
    xb = jnp.stack([x, x * 0.5 + 0.25])
    res = km.kmeans(xb, cfg=dm.TAYLOR, init=init, n_iters=3)
    assert res.centroids.shape == (2, 4, 8)
    assert res.assignments.shape == (2, 256)
    assert res.inertia.shape == (2,)
    assert res.inertia_trace.shape == (3, 2)
    # Batch member 0 must equal the unbatched run bit-for-bit.
    solo = km.kmeans(x, cfg=dm.TAYLOR, init=init, n_iters=3)
    np.testing.assert_array_equal(np.asarray(res.assignments[0]),
                                  np.asarray(solo.assignments))


def test_kmeans_empty_cluster_keeps_centroid(blobs):
    x, _ = blobs
    far = jnp.full((1, 8), 100.0, jnp.float32)   # no point will pick this
    init = jnp.concatenate([jnp.take(x, jnp.arange(3) * 80, axis=0), far])
    res = km.kmeans(x, cfg=dm.TAYLOR, init=init, n_iters=3)
    assert bool(jnp.all(jnp.isfinite(res.centroids)))
    np.testing.assert_allclose(np.asarray(res.centroids[3]), 100.0)


def test_kmeans_gradient_flows(blobs):
    x, init = blobs
    for mode, sched in [("taylor", "factored"), ("goldschmidt", "-")]:
        g = jax.grad(lambda v: km.kmeans(
            v, cfg=_cfg(mode, sched), init=init, n_iters=2).inertia)(x)
        assert bool(jnp.all(jnp.isfinite(g))), (mode, sched)
        assert float(jnp.max(jnp.abs(g))) > 0, (mode, sched)


def test_kmeans_empty_cluster_gradient_not_poisoned(blobs):
    """An empty cluster must not nan the gradient: the centroid update
    divides by max(count, 1), so even exact mode (no attach_grad masking)
    never differentiates through a 0/0 lane."""
    x, _ = blobs
    far = jnp.full((1, 8), 100.0, jnp.float32)   # captures no points
    init = jnp.concatenate([jnp.take(x, jnp.arange(3) * 80, axis=0), far])
    for cfg in (dm.EXACT, dm.TAYLOR):
        g = jax.grad(lambda v: km.kmeans(
            v, cfg=cfg, init=init, n_iters=2).inertia)(x)
        assert bool(jnp.all(jnp.isfinite(g))), cfg.mode
        assert float(jnp.max(jnp.abs(g))) > 0, cfg.mode


# --------------------------------------------------------------- Givens QR

QR_MODES = [("exact", "-"), ("taylor", "factored"), ("taylor", "paper"),
            ("goldschmidt", "-")]


@pytest.mark.parametrize("mode,sched", QR_MODES)
@pytest.mark.parametrize("via", ["div", "rsqrt"])
def test_qr_residual_gates(mode, sched, via):
    a = jax.random.normal(jax.random.PRNGKey(11), (16, 12), jnp.float32)
    q, r = qrw.qr_givens(a, _cfg(mode, sched), via=via)
    res = wm.qr_residuals(q, r, a)
    assert res["orthogonality"] <= 5e-6, (mode, via, res)
    assert res["reconstruction"] <= 5e-6, (mode, via, res)
    assert res["triangularity"] <= 5e-6, (mode, via, res)


def test_qr_matches_exact_twin():
    """Approximate-mode QR should sit within a few f32 ulps of the exact
    twin's factors — the divide errors must not amplify through rotations."""
    a = jax.random.normal(jax.random.PRNGKey(12), (12, 12), jnp.float32)
    qe, re_ = qrw.qr_givens(a, dm.EXACT)
    qt, rt = qrw.qr_givens(a, dm.TAYLOR)
    assert float(jnp.max(jnp.abs(qt - qe))) <= 1e-5
    scale = float(jnp.max(jnp.abs(re_)))
    assert float(jnp.max(jnp.abs(rt - re_))) <= 1e-5 * scale


def test_qr_shapes_and_edge_matrices():
    for shape in [(1, 1), (5, 3), (3, 5), (8, 8)]:
        a = jax.random.normal(jax.random.PRNGKey(13), shape, jnp.float32)
        q, r = qrw.qr_givens(a, dm.TAYLOR)
        assert q.shape == (shape[0], shape[0]) and r.shape == shape
        assert wm.reconstruction_residual(q, r, a) <= 1e-5
    # All-zero matrix: identity rotations throughout, no nan/inf.
    q, r = qrw.qr_givens(jnp.zeros((4, 3), jnp.float32), dm.TAYLOR)
    assert bool(jnp.all(jnp.isfinite(q)))
    np.testing.assert_array_equal(np.asarray(r), 0.0)


@pytest.mark.parametrize("via", ["div", "rsqrt"])
@pytest.mark.parametrize("scale", [1e20, 1e-18])
def test_qr_extreme_scale_safe_givens(via, scale):
    """a^2 + b^2 must not under/overflow f32 while the entries are normal:
    the rotation coefficients are computed on power-of-two-prescaled
    operands (safe Givens), so huge/tiny matrices still decompose."""
    base = jax.random.normal(jax.random.PRNGKey(15), (6, 4), jnp.float32)
    a = base * jnp.float32(scale)
    for cfg in (dm.EXACT, dm.TAYLOR):
        q, r = qrw.qr_givens(a, cfg, via=via)
        assert bool(jnp.all(jnp.isfinite(q))), (via, scale)
        res = wm.qr_residuals(q, r, a)
        assert res["orthogonality"] <= 5e-6, (via, scale, res)
        assert res["reconstruction"] <= 5e-6, (via, scale, res)


def test_qr_diagonal_nonnegative():
    """The (j, i) sweep with c = a/r >= 0 leaves a nonnegative diagonal on
    full-column-rank inputs."""
    a = jax.random.normal(jax.random.PRNGKey(14), (10, 6), jnp.float32)
    _, r = qrw.qr_givens(a, dm.TAYLOR)
    d = np.diag(np.asarray(r))
    assert np.all(d >= 0), d


# ----------------------------------------------- tiled fused divide kernel

def test_tiled_kernel_handles_ragged_shapes():
    from repro.kernels import tsdiv

    rng = np.random.default_rng(0)
    for shape in [(13, 200), (5, 1), (257, 129), (1, 300)]:
        a = jnp.asarray(np.ldexp(rng.uniform(1, 2, shape),
                                 rng.integers(-40, 40, shape)).astype(np.float32))
        b = jnp.asarray(np.ldexp(rng.uniform(1, 2, shape),
                                 rng.integers(-40, 40, shape)).astype(np.float32))
        y = np.asarray(tsdiv.tsdiv_divide_tiled_2d(a, b))
        ref = np.asarray(a) / np.asarray(b)
        np.testing.assert_allclose(y, ref, rtol=2e-7, err_msg=str(shape))


def test_tiled_kernel_bit_identical_to_padded_kernel():
    from repro.kernels import tsdiv

    rng = np.random.default_rng(1)
    shape = (16, 256)   # tile-aligned: both kernels apply
    a = jnp.asarray(np.ldexp(rng.uniform(1, 2, shape),
                             rng.integers(-40, 40, shape)).astype(np.float32))
    b = jnp.asarray(np.ldexp(rng.uniform(1, 2, shape),
                             rng.integers(-40, 40, shape)).astype(np.float32))
    for sched in ("factored", "paper", "goldschmidt"):
        t = np.asarray(tsdiv.tsdiv_divide_tiled_2d(a, b, schedule=sched))
        f = np.asarray(tsdiv.tsdiv_divide_2d(a, b, schedule=sched))
        assert np.array_equal(t.view(np.uint32), f.view(np.uint32)), sched


def test_tiled_kernel_edge_contract_in_ragged_tile():
    """IEEE special values sitting inside a ragged last tile."""
    from repro.kernels import tsdiv

    a = jnp.asarray([[0.0, -0.0, np.inf, -np.inf, np.nan, 1.0, 3.0]],
                    jnp.float32)
    b = jnp.asarray([[1.0, 2.0, 2.0, np.inf, 1.0, 0.0, -0.0]], jnp.float32)
    y = np.asarray(tsdiv.tsdiv_divide_tiled_2d(a, b), np.float64)
    expect = np.array([0.0, -0.0, np.inf, np.nan, np.nan, np.inf, -np.inf])
    np.testing.assert_array_equal(np.isnan(y[0]), np.isnan(expect))
    ok = ~np.isnan(expect)
    np.testing.assert_array_equal(y[0][ok], expect[ok])
    np.testing.assert_array_equal(np.signbit(y[0][ok]), np.signbit(expect[ok]))


def test_rank2_divide_dispatches_to_tiled_kernel(monkeypatch):
    """Pin the dispatch: a non-block-multiple 2D divide must run the tiled
    Pallas kernel — not the flatten-pad kernel, not the jnp fallback."""
    from repro.kernels import tsdiv as tsdiv_k

    calls = []
    real = tsdiv_k.tsdiv_divide_tiled_2d

    def spy(a, b, **kw):
        calls.append(a.shape)
        return real(a, b, **kw)

    def forbidden(*args, **kwargs):
        raise AssertionError("rank-2 divide fell back to the flatten path")

    monkeypatch.setattr(tsdiv_k, "tsdiv_divide_tiled_2d", spy)
    monkeypatch.setattr(tsdiv_k, "tsdiv_divide_2d", forbidden)
    a = jnp.full((13, 200), 6.0, jnp.float32)   # 13 % 8 != 0, 200 % 128 != 0
    b = jnp.full((13, 200), 3.0, jnp.float32)
    q = dm.div(a, b, dm.DivisionConfig(mode="taylor_pallas"))
    np.testing.assert_allclose(np.asarray(q), 2.0, rtol=1e-6)
    assert calls == [(13, 200)]
    # Batched (rank-3) operands collapse leading dims and stream too.
    calls.clear()
    ab = jnp.full((2, 13, 200), 6.0, jnp.float32)
    bb = jnp.full((2, 13, 200), 3.0, jnp.float32)
    qb = dm.div(ab, bb, dm.DivisionConfig(mode="taylor_pallas"))
    np.testing.assert_allclose(np.asarray(qb), 2.0, rtol=1e-6)
    assert calls == [(26, 200)]


def test_kernel_wrappers_accept_empty_arrays():
    """Empty operands must return empty results, not crash grid math."""
    from repro.kernels import ops as kops

    for shape in [(0,), (0, 4), (3, 0)]:
        e = jnp.ones(shape, jnp.float32)
        assert kops.tsdiv_divide(e, e).shape == shape
        assert kops.tsdiv_recip(e).shape == shape


def test_rank2_divide_gradient_analytic():
    from repro.kernels import ops as kops

    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.uniform(1, 2, (13, 200)).astype(np.float32))
    b = jnp.asarray(rng.uniform(1, 2, (13, 200)).astype(np.float32))
    ga, gb = jax.grad(lambda a, b: jnp.sum(kops.tsdiv_divide(a, b)),
                      argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga), np.asarray(1.0 / b), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(gb), np.asarray(-a / b ** 2),
                               rtol=1e-5)


def test_kmeans_pallas_mode_uses_tiled_kernel(monkeypatch):
    """The workload-level pin: K-Means' (N, K) and (K, D) divides stream
    through the tiled kernel when a Pallas mode is selected."""
    from repro.kernels import tsdiv as tsdiv_k

    shapes = []
    real = tsdiv_k.tsdiv_divide_tiled_2d

    def spy(a, b, **kw):
        shapes.append(a.shape)
        return real(a, b, **kw)

    monkeypatch.setattr(tsdiv_k, "tsdiv_divide_tiled_2d", spy)
    x = km.make_blobs(jax.random.PRNGKey(5), 48, 6, 3)
    init = jnp.take(x, jnp.arange(3) * 16, axis=0)
    km.kmeans(x, cfg=dm.DivisionConfig(mode="taylor_pallas"), init=init,
              n_iters=1)
    assert (48, 3) in shapes    # the assignment-distance plane
    assert (3, 6) in shapes     # the centroid update
    # Batched K-Means streams too (leading batch dim collapsed into rows).
    shapes.clear()
    km.kmeans(jnp.stack([x, x]), cfg=dm.DivisionConfig(mode="taylor_pallas"),
              init=init, n_iters=1)
    assert (96, 3) in shapes    # (2, 48, 3) distance planes
    assert (6, 6) in shapes     # (2, 3, 6) centroid updates
