"""Paper §4-5: Iterative Logarithmic Multiplier — exactness + error decay."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import ilm


class TestNumpyILM:
    @given(st.integers(1, 2**24 - 1), st.integers(1, 2**24 - 1))
    @settings(max_examples=200, deadline=None)
    def test_exact_at_full_iterations(self, a, b):
        p = int(ilm.ilm_mul_np(a, b, 24)[()])
        assert p == a * b

    @given(st.integers(1, 2**24 - 1))
    @settings(max_examples=200, deadline=None)
    def test_square_exact(self, a):
        s = int(ilm.ilm_square_np(a, 24)[()])
        assert s == a * a

    def test_error_decays_monotonically(self, rng):
        a = rng.integers(1, 2**16, 5000).astype(np.uint64)
        b = rng.integers(1, 2**16, 5000).astype(np.uint64)
        exact = a * b
        prev = None
        for iters in range(1, 17):
            p = ilm.ilm_mul_np(a, b, iters)
            err = np.sum((exact - p).astype(np.float64))
            assert np.all(p <= exact)  # ILM underestimates (truncates E >= 0)
            if prev is not None:
                assert err <= prev
            prev = err
        assert prev == 0.0

    def test_one_iteration_is_mitchell(self, rng):
        """iters=1 reproduces Mitchell's algorithm error profile (<= 25%)."""
        a = rng.integers(1, 2**20, 10_000).astype(np.uint64)
        b = rng.integers(1, 2**20, 10_000).astype(np.uint64)
        p = ilm.ilm_mul_np(a, b, 1)
        rel = (a * b - p).astype(np.float64) / (a * b).astype(np.float64)
        assert rel.max() <= 0.25 + 1e-9  # Mitchell's known worst case
        assert rel.max() > 0.10          # and it's really the approximate path

    def test_floor_log2(self):
        xs = np.asarray([1, 2, 3, 4, 7, 8, 255, 256, 2**31], np.uint64)
        out = ilm.floor_log2_np(xs)
        assert list(out) == [0, 1, 1, 2, 2, 3, 7, 8, 31]


class TestJnpILM:
    @given(st.integers(1, 2**16 - 1), st.integers(1, 2**16 - 1),
           st.integers(1, 16))
    @settings(max_examples=100, deadline=None)
    def test_matches_numpy_twin(self, a, b, iters):
        pj = int(ilm.ilm_mul(jnp.uint32(a), jnp.uint32(b), iters))
        pn = int(ilm.ilm_mul_np(a, b, iters)[()])
        assert pj == pn

    @given(st.integers(1, 2**16 - 1))
    @settings(max_examples=100, deadline=None)
    def test_square_exact_16bit(self, a):
        assert int(ilm.ilm_square(jnp.uint32(a), 16)) == a * a


class TestFpEmulation:
    def test_fp_mul_accuracy_by_iters(self, rng):
        x = rng.uniform(-100, 100, 2000)
        y = rng.uniform(0.01, 100, 2000)
        prev = None
        for iters in (1, 2, 4, 8, 24):
            p = ilm.fp_mul_ilm_np(x, y, iters=iters, mant_bits=24)
            rel = np.max(np.abs(p - x * y) / np.abs(x * y))
            if prev is not None:
                assert rel <= prev * (1 + 1e-12)
            prev = rel
        assert prev < 1e-6  # full iterations ~ exact at 24-bit quantization

    def test_full_datapath_recip(self, rng):
        """Fig. 7 system: PWL seed + ILM-powered Taylor series, end to end."""
        x = rng.uniform(1.0, 2.0, 500)
        r = ilm.fp_recip_ilm_np(x, iters_mul=24, n_terms=5)
        assert np.max(np.abs(r * x - 1.0)) < 2**-22  # 24-bit mantissa regime
