"""Sharded-vs-single-device numerics pins for the mesh-aware division unit.

The PR-7 acceptance gates, each under a forced 8-device host platform
(subprocess: jax locks the device count at first init):

  * shard_map'd tiled divide/recip dispatch is bit-identical to the
    single-device kernels on ragged production shapes, and compiles with
    ZERO collectives — while the naive path (direct pallas_call under jit
    on sharded operands) demonstrably all-gathers;
  * sharded rsqrt dispatch is bit-identical to the single-device tiled
    rsqrt kernel on the same shard layout;
  * data-parallel K-Means at 10^6 points matches the unsharded run's
    assignments exactly and centroids to <= 1 int ulp, with the centroid
    divide consuming globally-reduced sums/counts (the psum/all-gather wire
    bytes in the HLO match launch/roofline.py's analytic models);
  * sharded batched Givens QR is bit-identical to the single-device batch.

Bit-identity note (docs/numerics.md): these pins hold at grid > 1 tile
geometries on both sides. Tiny grid-(1,1) mostly-masked launches can drift
1 ulp against other geometries (XLA CPU codegen variance at inlined small
shapes, same class as tests/test_jit_drift.py) — which is why the shapes
here are production-sized and ragged, not minimal.
"""
import subprocess
import sys

_ENV8 = 'os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"'


def _run(snippet: str, sentinel: str):
    r = subprocess.run([sys.executable, "-c", snippet],
                       capture_output=True, text=True, timeout=600,
                       env={**__import__("os").environ, "PYTHONPATH": "src",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert sentinel in r.stdout, r.stdout + r.stderr


DIVIDE_SNIPPET = f"""
import os
{_ENV8}
import jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.launch import roofline as rl
from repro.sharding import rules as shr
from repro.kernels import ops, tsdiv as tsdiv_k

mesh = make_host_mesh()
for rows, cols in ((1992, 300), (2048, 384)):
    a = jax.random.uniform(jax.random.PRNGKey(0), (rows, cols), jnp.float32,
                           0.1, 10.0)
    b = jax.random.uniform(jax.random.PRNGKey(1), (rows, cols), jnp.float32,
                           0.1, 10.0)
    ref = ops.tsdiv_divide(a, b)                  # no mesh: plain launch
    sh = shr.data_sharding(mesh, 2, batch_size=rows)
    a_s, b_s = jax.device_put(a, sh), jax.device_put(b, sh)
    with shr.use_mesh(mesh):
        got = ops.tsdiv_divide(a_s, b_s)
    assert bool(jnp.all(got.view(jnp.int32) == ref.view(jnp.int32))), \\
        f"sharded divide not bit-identical at {{(rows, cols)}}"

# Compiled artifact checks at (2048, 384): the sharded dispatch must stay
# collective-free with per-shard-resident HBM traffic ...
rows, cols = 2048, 384
with shr.use_mesh(mesh):
    f_sh = jax.jit(lambda u, v: ops.tsdiv_divide(u, v))
    c_sh = f_sh.lower(a_s, b_s).compile()
hlo = c_sh.as_text()
colls = rl.parse_collectives(hlo, 8)
assert not colls["ops"], f"sharded dispatch compiled collectives: {{colls['ops']}}"
cost = c_sh.cost_analysis()
if isinstance(cost, (list, tuple)):
    cost = cost[0]
model = rl.elementwise_hbm_bytes(rows * cols, n_operands=2, n_results=1)
measured = float(cost.get("bytes accessed", 0.0))
assert 0.7 * model <= measured <= 1.5 * model, \\
    f"HBM traffic {{measured}} vs elementwise model {{model}}"

# ... while the naive path (direct tiled pallas_call under jit, no
# shard_map) silently all-gathers the sharded operands: the bug this PR
# fixes, pinned so it stays visible. Needs a grid > 1 shape — at grid
# (1, 1) interpret-pallas inlines to partitionable elementwise HLO.
a2 = jax.random.uniform(jax.random.PRNGKey(2), (2048, 512), jnp.float32,
                        0.1, 10.0)
a2_s = jax.device_put(a2, shr.data_sharding(mesh, 2, batch_size=2048))
f_naive = jax.jit(lambda u, v: tsdiv_k.tsdiv_divide_tiled_2d(u, v))
hlo_naive = f_naive.lower(a2_s, a2_s).compile().as_text()
assert "all-gather" in hlo_naive, "naive pallas jit no longer all-gathers?"
print("DIVIDE8 OK")
"""


def test_sharded_divide_bit_identity_and_no_collectives():
    """Tiled divide: sharded == single-device bitwise; zero collectives;
    HBM traffic matches the elementwise model; naive path all-gathers."""
    _run(DIVIDE_SNIPPET, "DIVIDE8 OK")


RECIP_RSQRT_SNIPPET = f"""
import os
{_ENV8}
import jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.launch import roofline as rl
from repro.sharding import rules as shr
from repro.kernels import ops, tsdiv as tsdiv_k

mesh = make_host_mesh()
rows, cols = 1992, 300
x = jax.random.uniform(jax.random.PRNGKey(0), (rows, cols), jnp.float32,
                       0.05, 50.0)
ref_recip = ops.tsdiv_recip(x)                    # no mesh: flatten path
ref_rsqrt = tsdiv_k.tsdiv_rsqrt_tiled_2d(x)       # single-device tiled kernel
x_s = jax.device_put(x, shr.data_sharding(mesh, 2, batch_size=rows))
with shr.use_mesh(mesh):
    got_recip = ops.tsdiv_recip(x_s)
    got_rsqrt = ops.tsdiv_rsqrt(x_s)
    f = jax.jit(lambda v: ops.tsdiv_rsqrt(v))
    hlo = f.lower(x_s).compile().as_text()
assert bool(jnp.all(got_recip.view(jnp.int32) == ref_recip.view(jnp.int32)))
assert bool(jnp.all(got_rsqrt.view(jnp.int32) == ref_rsqrt.view(jnp.int32)))
assert not rl.parse_collectives(hlo, 8)["ops"], "sharded rsqrt has collectives"
print("RECIPRSQRT8 OK")
"""


def test_sharded_recip_rsqrt_bit_identity():
    """recip/rsqrt dispatch: sharded == single-device bitwise, no
    collectives."""
    _run(RECIP_RSQRT_SNIPPET, "RECIPRSQRT8 OK")


KMEANS_SNIPPET = f"""
import os
{_ENV8}
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import make_host_mesh
from repro.launch import roofline as rl
from repro.sharding import rules as shr
from repro.core import division_modes as dm
from repro.eval.ulp import ulp_diff
from repro.workloads import kmeans as km

mesh = make_host_mesh()
N, D, K, ITERS = 1_000_000, 8, 8, 3
cfg = dm.DivisionConfig(mode="taylor")
x = km.make_blobs(jax.random.PRNGKey(0), N, D, K)
init = jnp.take(x, jnp.arange(K) * (N // K), axis=0)

ref = km.kmeans(x, cfg=cfg, n_iters=ITERS, init=init)
x_s = jax.device_put(x, shr.data_sharding(mesh, 2, batch_size=N))
with shr.use_mesh(mesh):
    got = km.kmeans_sharded(x_s, cfg=cfg, n_iters=ITERS, init=init)

assert bool(jnp.all(ref.assignments == got.assignments)), \\
    "sharded K-Means assignments differ from the unsharded run"
ud = ulp_diff(np.asarray(ref.centroids), np.asarray(got.centroids))
assert int(ud.max()) <= 1, f"centroids drifted {{int(ud.max())}} int ulp"

# The centroid divide must consume globally-reduced operands: the compiled
# HLO carries the group-8 reductions, with wire bytes matching the
# analytic models (counts: psum of K f32; sums: shard-ordered all-gather
# of the (K, D) block partials).
with shr.use_mesh(mesh):
    f = jax.jit(lambda xx, ii: km.kmeans_sharded(
        xx, cfg=cfg, n_iters=ITERS, init=ii).centroids)
    hlo = f.lower(x_s, init).compile().as_text()
ops_ = rl.parse_collectives(hlo, 8)["ops"]
ars = [o for o in ops_ if o["op"] == "all-reduce" and o["group"] == 8]
ags = [o for o in ops_ if o["op"] == "all-gather" and o["group"] == 8]
assert any(o["wire_bytes"] == rl.allreduce_wire_bytes(K, 8) for o in ars), \\
    f"no psum-of-counts matching the {{K}}-lane model: {{ops_}}"
assert any(o["bytes"] == 8 * K * D * 4 for o in ags), \\
    f"no all-gather of the (8, K, D) sum partials: {{ops_}}"
print("KMEANS8 OK")
"""


def test_sharded_kmeans_production_scale():
    """10^6-point data-parallel K-Means over 8 devices: assignments exact,
    centroids <= 1 int ulp, globally-reduced operands in the HLO."""
    _run(KMEANS_SNIPPET, "KMEANS8 OK")


QR_SNIPPET = f"""
import os
{_ENV8}
import jax, jax.numpy as jnp
from repro.launch.mesh import make_host_mesh
from repro.sharding import rules as shr
from repro.core import division_modes as dm
from repro.workloads import qr as qrw

mesh = make_host_mesh()
cfg = dm.DivisionConfig(mode="taylor")
a = jax.random.normal(jax.random.PRNGKey(3), (16, 12, 8), jnp.float32)
for via in ("div", "rsqrt"):
    q_ref, r_ref = qrw.qr_givens_batched(a, cfg, via=via)
    with shr.use_mesh(mesh):
        q_got, r_got = qrw.qr_givens_sharded(a, cfg, via=via)
    assert bool(jnp.all(q_ref.view(jnp.int32) == q_got.view(jnp.int32))), via
    assert bool(jnp.all(r_ref.view(jnp.int32) == r_got.view(jnp.int32))), via
print("QR8 OK")
"""


def test_sharded_qr_bit_identity():
    """Sharded batched Givens QR == single-device batch, bitwise, both
    rotation-coefficient formulations."""
    _run(QR_SNIPPET, "QR8 OK")


def test_kmeans_sharded_fallback_without_mesh():
    """No active mesh (or nothing divides): kmeans_sharded IS kmeans."""
    import jax
    import jax.numpy as jnp

    from repro.core import division_modes as dm
    from repro.workloads import kmeans as km

    cfg = dm.DivisionConfig(mode="taylor")
    x = km.make_blobs(jax.random.PRNGKey(0), 512, 4, 3)
    init = jnp.take(x, jnp.arange(3) * 100, axis=0)
    a = km.kmeans(x, cfg=cfg, n_iters=3, init=init)
    b = km.kmeans_sharded(x, cfg=cfg, n_iters=3, init=init)
    assert bool(jnp.all(a.assignments == b.assignments))
    assert bool(jnp.all(a.centroids == b.centroids))


def test_qr_batched_matches_loop():
    """qr_givens_batched == per-matrix qr_givens (vmap changes no numerics
    the residual tests rely on; allclose, not bitwise — vmap may reorder
    elementwise fusion)."""
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core import division_modes as dm
    from repro.workloads import qr as qrw

    cfg = dm.DivisionConfig(mode="taylor")
    a = jax.random.normal(jax.random.PRNGKey(5), (3, 10, 6), jnp.float32)
    qb, rb = qrw.qr_givens_batched(a, cfg)
    for i in range(a.shape[0]):
        qi, ri = qrw.qr_givens(a[i], cfg)
        np.testing.assert_allclose(np.asarray(qb[i]), np.asarray(qi),
                                   rtol=0, atol=1e-6)
        np.testing.assert_allclose(np.asarray(rb[i]), np.asarray(ri),
                                   rtol=0, atol=1e-6)
