"""division_modes: the framework-wide dispatch over the paper's unit."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import division_modes as dm


MODES = ["exact", "taylor", "taylor_pallas"]


@pytest.mark.parametrize("mode", MODES)
def test_recip_all_modes(rng, mode):
    cfg = dm.DivisionConfig(mode=mode)
    x = jnp.asarray(rng.uniform(0.1, 100, (64,)), jnp.float32)
    r = dm.recip(x, cfg)
    rel = np.abs(np.asarray(r) * np.asarray(x) - 1)
    assert rel.max() < 1e-5


@pytest.mark.parametrize("mode", MODES)
def test_softmax_all_modes(rng, mode):
    cfg = dm.DivisionConfig(mode=mode)
    x = jnp.asarray(rng.normal(size=(8, 32)), jnp.float32) * 4
    s = dm.softmax(x, -1, cfg)
    np.testing.assert_allclose(np.asarray(s).sum(-1), 1.0, rtol=1e-4)
    e = jax.nn.softmax(x, -1)
    np.testing.assert_allclose(np.asarray(s), np.asarray(e), atol=1e-5)


def test_softmax_masked(rng):
    x = jnp.asarray(rng.normal(size=(4, 16)), jnp.float32)
    where = jnp.arange(16)[None, :] < 10
    s = dm.softmax(x, -1, dm.TAYLOR, where=where)
    assert np.allclose(np.asarray(s)[:, 10:], 0.0)
    np.testing.assert_allclose(np.asarray(s).sum(-1), 1.0, rtol=1e-4)


def test_ilm_mode_runs_and_is_approximate(rng):
    cfg = dm.DivisionConfig(mode="ilm")
    x = jnp.asarray(rng.uniform(1.0, 2.0, (32,)), jnp.float32)
    r = dm.recip(x, cfg)
    rel = np.abs(np.asarray(r) * np.asarray(x) - 1)
    assert rel.max() < 5e-3  # 12-bit mantissa regime
    assert rel.max() > 1e-8  # genuinely the approximate datapath


def test_div_and_rsqrt(rng):
    a = jnp.asarray(rng.normal(size=(32,)), jnp.float32) * 10
    b = jnp.asarray(rng.uniform(0.5, 50, (32,)), jnp.float32)
    q = dm.div(a, b, dm.TAYLOR)
    np.testing.assert_allclose(np.asarray(q), np.asarray(a / b),
                               rtol=1e-5, atol=1e-6)
    r = dm.rsqrt(b, dm.TAYLOR)
    np.testing.assert_allclose(np.asarray(r), 1 / np.sqrt(np.asarray(b)),
                               rtol=1e-5)


def test_precision_dial_matches_eq17(rng):
    """Lower n => larger error, bounded by the table's eq.17 bound."""
    x = jnp.asarray(rng.uniform(0.5, 4.0, (4096,)), jnp.float32)
    errs = []
    for n, prec in [(1, 12), (2, 24), (3, 30)]:
        cfg = dm.DivisionConfig(mode="taylor", n_iters=n, precision_bits=prec)
        r = dm.recip(x, cfg)
        rel = float(np.max(np.abs(np.asarray(r) * np.asarray(x) - 1)))
        assert rel <= cfg.table.max_error_bound() + 2**-21
        errs.append(rel)
    assert errs[0] > errs[2]


def test_grad_through_all_modes():
    for mode in MODES:
        cfg = dm.DivisionConfig(mode=mode)
        g = jax.grad(lambda v: dm.recip(v, cfg).sum())(jnp.float32(4.0))
        assert abs(float(g) + 1 / 16) < 1e-4, mode
