"""Inject generated roofline tables into EXPERIMENTS.md (between markers).

  PYTHONPATH=src python experiments/finalize_report.py
"""
import json
import re
import sys

sys.path.insert(0, "src")

from repro.launch.report import load_cells, table  # noqa: E402

EXP = "EXPERIMENTS.md"


def variant_table(cells, arch, shape, variants):
    rows = {c["variant"]: c for c in cells
            if c["arch"] == arch and c["shape"] == shape
            and c["mesh"] == "single"}
    out = ["| variant | t_compute | t_memory | t_collective | bound | t_step | MFU |",
           "|---|---|---|---|---|---|---|"]
    for v in variants:
        c = rows.get(v)
        if not c:
            continue
        r = c["roofline"]
        out.append(
            f"| `{v}` | {r['t_compute']*1e3:.1f}ms | {r['t_memory']*1e3:.1f}ms "
            f"| {r['t_collective']*1e3:.1f}ms | {r['bound']} "
            f"| {r['t_step']*1e3:.1f}ms | {r['mfu']:.3f} |")
    return "\n".join(out)


def main():
    cells = load_cells("experiments/dryrun")
    single = table(cells, "single", "base")
    multi = table(cells, "multi", "base")

    dryrun_block = (
        "### Single-pod (data=16, model=16), 256 chips — baseline variant\n\n"
        + single +
        "\n\n### Multi-pod (pod=2, data=16, model=16), 512 chips — baseline\n\n"
        + multi +
        "\n\nNotes: `fits` checks params+opt+temps against 16 GB/chip. "
        "Baseline **NO** cells fall into two classes, both engineered away in "
        "§Perf: (1) decode at TP16 replicates the KV cache per model rank "
        "(fixed by TP<=kv_heads: the tp4/tp8 decode variants fit and run at "
        "the HBM roofline); (2) CPU-backend `temp` accounting holds every "
        "loop iteration's buffers live simultaneously — argument bytes "
        "(params+optimizer, exact) fit everywhere, including Jamba-398B at "
        "8.8 GiB/device. MFU is meaningless for decode cells (memory-bound "
        "by construction); their roofline fraction is t_memory/t_step.\n")

    hillclimb_tables = []
    for arch, shape, variants, title in [
        ("llama3_8b", "train_4k",
         ["base", "exact_div", "div_paper_n5", "tp8", "tp4", "tp4+seq_shard",
          "tp4+flash", "tp4+flash+optbf16",
          "tp4+flash+no_remat+optbf16+mb2"],
         "Cell A: llama3_8b × train_4k"),
        ("llama3_8b", "decode_32k",
         ["base", "kvseq", "tp4+flash", "tp8+kvseq+flash"],
         "Cell B: llama3_8b × decode_32k"),
        ("deepseek_moe_16b", "train_4k",
         ["base", "sort_dispatch", "local_dispatch",
          "local_dispatch+ep_tp+tp4+flash+no_remat",
          "local_dispatch+tp4+flash+no_remat+optbf16"],
         "Cell C: deepseek_moe_16b × train_4k"),
        ("jamba_1_5_large", "train_4k",
         ["base", "sort_dispatch+mb4", "local_dispatch+mb4"],
         "Bonus: jamba_1_5_large × train_4k"),
        ("moonshot_v1_16b_a3b", "train_4k",
         ["base", "local_dispatch+ep_tp+tp4",
          "local_dispatch+tp4+flash+optbf16"],
         "Bonus: moonshot × train_4k"),
    ]:
        hillclimb_tables.append(f"### {title} (measured variants)\n\n"
                                + variant_table(cells, arch, shape, variants))
    perf_block = "\n\n".join(hillclimb_tables)

    with open(EXP) as f:
        text = f.read()
    text = re.sub(r"<!-- DRYRUN-TABLES -->.*?(?=## §Roofline)",
                  "<!-- DRYRUN-TABLES -->\n\n" + dryrun_block + "\n",
                  text, flags=re.S)
    # idempotent: replace the whole §Roofline section body
    text = re.sub(
        r"## §Roofline.*?## §Perf",
        "## §Roofline\n\n<!-- ROOFLINE-TABLE -->\n\n"
        "The three terms per cell are in the §Dry-run tables above "
        "(t_compute / t_memory / t_collective columns, dominant term "
        "bolded); below are the measured hillclimb variants referenced by "
        "§Perf.\n\n" + perf_block + "\n\n## §Perf",
        text, flags=re.S)
    with open(EXP, "w") as f:
        f.write(text)
    print("EXPERIMENTS.md updated")


if __name__ == "__main__":
    main()
